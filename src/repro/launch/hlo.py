"""Optimized-HLO text analysis: FLOPs, collective wire bytes, while-loop
trip counts — the dry-run profiler (no real hardware, the IR is the trace).

XLA's built-in cost analysis visits while bodies ONCE; for scan-over-layers
programs that undercounts by num_layers.  This parser builds the call graph
(entry -> fusions/calls/while bodies), recovers trip counts from while
*condition* computations (`compare(iv, constant(N)), direction=LT`), and
propagates costs bottom-up with multipliers.

Counted:
  * dot FLOPs: 2 * prod(output shape) * prod(lhs contracting dims)
  * collective wire bytes per participating device, ring-model factors:
      all-gather       (g-1)/g * out_bytes
      reduce-scatter   (g-1)/g * in_bytes
      all-reduce       2 (g-1)/g * bytes
      all-to-all       (g-1)/g * bytes
      collective-permute  bytes
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)%?([\w\.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_elems(dtype: str, dims: str) -> tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, _DTYPE_BYTES.get(dtype, 4) * n


def _first_shape(line: str, after: str = "=") -> tuple[int, int] | None:
    """(elements, bytes) of the first shape literal after `after`."""
    idx = line.find(after)
    m = _SHAPE_RE.search(line, idx + 1)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    return _shape_elems(m.group(1), m.group(2))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    collective_bytes: float = 0.0           # wire bytes per device
    collective_ops: dict | None = None

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.collective_bytes += other.collective_bytes
        for k, v in (other.collective_ops or {}).items():
            self.collective_ops[k] = self.collective_ops.get(k, 0.0) + v
        return self


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota form [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def _dot_flops(line: str, shapes: dict[str, list[int]]) -> float:
    out = _first_shape(line, "=")
    if out is None:
        return 0.0
    out_elems = out[0]
    # The lhs operand is printed either as a typed literal
    # (`dot(f32[8,16]{1,0} %arg, ...)`) or as a bare name (`dot(%arg, ...)`)
    # depending on the XLA version/backend; accept both.
    m = re.search(r"dot\(\s*(?:(\w+)\[([\d,]*)\]\S*\s+)?%?([\w\.\-]+)", line)
    if not m:
        return 0.0
    if m.group(1) is not None and m.group(1) in _DTYPE_BYTES:
        lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    else:
        lhs_dims = shapes.get(m.group(3))
    if lhs_dims is None:
        return 0.0
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if mc:
        for d in mc.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)]
    return 2.0 * out_elems * contract


class HLOAnalysis:
    def __init__(self, hlo_text: str, num_devices: int):
        self.num_devices = num_devices
        self.computations: dict[str, list[str]] = {}
        self.trip_counts: dict[str, float] = {}
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}
        self.entry_cost = self._cost(self.entry)

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        current = None
        self.entry = None
        self.shapes: dict[str, list[int]] = {}
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith("//"):
                continue
            if line.startswith(("HloModule",)):
                continue
            head = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
            if head and not line.startswith("ROOT") and "= " not in line.split("{")[0]:
                current = head.group(2)
                self.computations[current] = []
                if head.group(1):
                    self.entry = current
                continue
            if line.startswith("}"):
                continue
            if current is not None:
                self.computations[current].append(line)
                ms = re.match(
                    r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\]", line)
                if ms and ms.group(2) in _DTYPE_BYTES:
                    self.shapes[ms.group(1)] = [
                        int(d) for d in ms.group(3).split(",") if d]
        if self.entry is None:
            # fall back: computation literally named main
            for name in self.computations:
                if "main" in name:
                    self.entry = name
                    break

    def _cond_trip_count(self, cond_name: str) -> float:
        """Largest plausible integer constant in the while condition ~ trip
        count (scan bounds; sentinel constants like INT_MAX are ignored)."""
        best = 1
        for line in self.computations.get(cond_name, ()):
            for m in re.finditer(r"constant\((\d+)\)", line):
                v = int(m.group(1))
                if v <= 1_000_000:
                    best = max(best, v)
        return float(best)

    # ---------------------------------------------------------------- cost
    def _cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost(collective_ops={})
        self._memo[comp] = total     # break cycles defensively
        for line in self.computations.get(comp, ()):
            op = self._opcode(line)
            if op == "while":
                body = self._called(line, "body=")
                cond = self._called(line, "condition=")
                trips = self._cond_trip_count(cond) if cond else 1.0
                if body:
                    sub = self._cost(body)
                    total += Cost(
                        sub.flops * trips, sub.collective_bytes * trips,
                        {k: v * trips for k, v in sub.collective_ops.items()},
                    )
                continue
            if op == "dot":
                total += Cost(_dot_flops(line, self.shapes), 0.0, {})
            elif op in ("all-gather", "all-gather-start"):
                sh = _first_shape(line)
                if sh:
                    g = _group_size(line, self.num_devices)
                    wire = sh[1] * (g - 1) / g
                    total += Cost(0.0, wire, {"all-gather": wire})
            elif op in ("all-reduce", "all-reduce-start"):
                sh = _first_shape(line)
                if sh:
                    g = _group_size(line, self.num_devices)
                    wire = 2.0 * sh[1] * (g - 1) / g
                    total += Cost(0.0, wire, {"all-reduce": wire})
            elif op == "reduce-scatter":
                sh = _first_shape(line)   # output (already scattered)
                if sh:
                    g = _group_size(line, self.num_devices)
                    wire = sh[1] * (g - 1)
                    total += Cost(0.0, wire, {"reduce-scatter": wire})
            elif op == "all-to-all":
                sh = _first_shape(line)
                if sh:
                    g = _group_size(line, self.num_devices)
                    wire = sh[1] * (g - 1) / g
                    total += Cost(0.0, wire, {"all-to-all": wire})
            elif op in ("collective-permute", "collective-permute-start"):
                sh = _first_shape(line)
                if sh:
                    total += Cost(0.0, sh[1], {"collective-permute": sh[1]})
            # descend into fusions / calls / conditionals (cost counted once
            # per call site; XLA emits one op line per call site)
            for target in self._all_called(line, op):
                total += self._cost(target)
        self._memo[comp] = total
        return total

    @staticmethod
    def _opcode(line: str) -> str:
        # strip /*index=N*/ comments inside tuple types, then take the first
        # lowercase identifier followed by '(' after the '=' — type literals
        # (f32[...], pred[...]) never match because they end in '['.
        line = re.sub(r"/\*.*?\*/", "", line)
        eq = line.find("= ")
        if eq < 0:
            return ""
        m = re.search(r"([a-z][\w\-]*)\(", line[eq + 2:])
        return m.group(1) if m else ""

    def _called(self, line: str, key: str) -> str | None:
        idx = line.find(key)
        if idx < 0:
            return None
        m = re.match(r"%?([\w\.\-]+)", line[idx + len(key):])
        return m.group(1) if m else None

    def _all_called(self, line: str, op: str) -> list[str]:
        if op == "while":
            return []
        out = []
        for key in ("calls=", "to_apply="):
            t = self._called(line, key)
            # reducers (to_apply of reduce/all-reduce) are trivial adds —
            # still descended; they contain no dots/collectives.
            if t:
                out.append(t)
        m = re.search(r"branch_computations=\{([^}]*)\}", line)
        if m:
            out += [x.strip().lstrip("%") for x in m.group(1).split(",")]
        return out

    # --------------------------------------------------------------- report
    def summary(self) -> dict:
        return {
            "flops": self.entry_cost.flops,
            "collective_wire_bytes_per_device": self.entry_cost.collective_bytes,
            "collective_breakdown": dict(self.entry_cost.collective_ops),
        }

    def collective_sites(self, top: int = 12) -> list[dict]:
        """Per-site wire bytes x loop multiplier — the §Perf debugging view:
        which collective, in which loop nest, moves the bytes."""
        mults: dict[str, float] = defaultdict(float)
        mults[self.entry] = 1.0
        order = [self.entry]
        seen = {self.entry}
        i = 0
        while i < len(order):          # BFS over the call graph
            comp = order[i]
            i += 1
            for line in self.computations.get(comp, ()):
                op = self._opcode(line)
                if op == "while":
                    body = self._called(line, "body=")
                    cond = self._called(line, "condition=")
                    trips = self._cond_trip_count(cond) if cond else 1.0
                    if body:
                        mults[body] += mults[comp] * trips
                        if body not in seen:
                            seen.add(body)
                            order.append(body)
                else:
                    for t in self._all_called(line, op):
                        mults[t] += mults[comp]
                        if t not in seen:
                            seen.add(t)
                            order.append(t)
        sites = []
        for comp, lines in self.computations.items():
            if comp not in mults:
                continue
            for line in lines:
                op = self._opcode(line)
                if op.split("-start")[0] not in (
                    "all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute",
                ):
                    continue
                sh = _first_shape(line)
                if not sh:
                    continue
                mname = re.search(r'op_name="([^"]*)"', line)
                sites.append({
                    "op": op, "comp": comp, "mult": mults[comp],
                    "bytes_per_exec": sh[1],
                    "total_bytes": sh[1] * mults[comp],
                    "op_name": (mname.group(1)[-120:] if mname else ""),
                })
        sites.sort(key=lambda s: -s["total_bytes"])
        return sites[:top]
