"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

`input_specs(cfg, cell)` returns (batch_specs, cache_specs|None): weak-type-
correct, shardable, zero allocation.  Shapes follow the assignment's cells:

  train_4k     -> train_step inputs  (microbatched per `microbatch_plan`)
  prefill_32k  -> prefill inputs + an empty cache to fill
  decode_32k   -> serve_step: ONE new token against a seq_len KV cache
  long_500k    -> serve_step at 524288 context (sub-quadratic archs only)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell, microbatch_plan
from repro.models.model import init_cache

PyTree = Any

_I32 = jnp.int32


def _token_like(cfg: ModelConfig, b: int, s: int, with_targets: bool) -> dict:
    d = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        specs = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), d),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
        }
        if with_targets:
            specs["targets"] = jax.ShapeDtypeStruct((b, s), _I32)
            specs["target_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
        return specs
    if cfg.family == "vlm":
        sv = s // 4
        st = s - sv
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, st), _I32),
            "patch_embeds": jax.ShapeDtypeStruct((b, sv, cfg.d_model), d),
            "positions": jax.ShapeDtypeStruct((b, 3, s), _I32),
        }
        if with_targets:
            specs["targets"] = jax.ShapeDtypeStruct((b, st), _I32)
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), _I32)}
    if with_targets:
        specs["targets"] = jax.ShapeDtypeStruct((b, s), _I32)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, capacity: int) -> PyTree:
    """Cache ShapeDtypeStructs without allocating (eval_shape over init)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity))


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                data_shards: int = 16) -> tuple[PyTree, PyTree | None, int]:
    """Returns (batch_specs, cache_specs | None, accum)."""
    if cell.kind == "train":
        accum, per_step = microbatch_plan(cfg, cell, data_shards)
        specs = _token_like(cfg, per_step, cell.seq_len, with_targets=True)
        if accum > 1:
            specs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((accum,) + s.shape, s.dtype),
                specs,
            )
        return specs, None, accum
    if cell.kind == "prefill":
        specs = _token_like(cfg, cell.global_batch, cell.seq_len,
                            with_targets=False)
        specs["prompt_lens"] = jax.ShapeDtypeStruct((cell.global_batch,), _I32)
        cache = cache_specs(cfg, cell.global_batch, cell.seq_len)
        return specs, cache, 1
    # decode: one new token against a cache of length seq_len
    b = cell.global_batch
    specs = {"tokens": jax.ShapeDtypeStruct((b, 1), _I32)}
    if cfg.m_rope:
        specs["positions"] = jax.ShapeDtypeStruct((b, 3, 1), _I32)
    cache = cache_specs(cfg, b, cell.seq_len)
    return specs, cache, 1
