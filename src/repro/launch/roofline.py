"""Three-term roofline from the compiled dry-run artifact (TPU v5e targets).

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HBM bytes / (chips x 819 GB/s)
    collective term = wire bytes per chip / 50 GB/s per link

HLO_FLOPs and collective bytes come from the HLO parser (`launch.hlo`) with
while-loop trip multipliers.  The memory term uses the *compulsory* HBM
traffic of the program (weights read once per step, KV cache read+written,
microbatch activation checkpoints spilled once each) — the roofline floor a
perfect fusion could reach; `memory_analysis()` per-device residency is
reported alongside as the capacity check.

MODEL_FLOPS = 6*N*D (dense train; N = active params, D = tokens) or 2*N*D
(forward-only) measures how much of the compiled compute is "useful" —
catching remat recompute and causal-mask waste.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    chips: int
    hlo_flops: float
    model_flops: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    memory_residency_per_chip: float | None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat & masking waste shows up here)."""
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOP/s at the bound, as a fraction of peak compute:
        the report's headline 'how close to roofline' number."""
        useful_per_chip = self.model_flops / self.chips
        return useful_per_chip / (self.bound_s * PEAK_FLOPS)

    def row(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """6*N_active*D for training, 2*N_active*D for forward-only, plus the
    attention term 12*L_attn*h*s*D_factor where applicable."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        base = 6.0 * n * tokens
        attn = (12.0 * cfg.num_attention_applications()
                * cfg.num_heads * cfg.resolved_head_dim
                * cell.seq_len * tokens / 2)      # causal: half the square
        return base + attn
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        base = 2.0 * n * tokens
        attn = (4.0 * cfg.num_attention_applications()
                * cfg.num_heads * cfg.resolved_head_dim
                * cell.seq_len * tokens / 2)
        return base + attn
    # decode: one token per request against a seq_len cache
    tokens = cell.global_batch
    base = 2.0 * n * tokens
    attn = (4.0 * cfg.num_attention_applications()
            * cfg.num_heads * cfg.resolved_head_dim
            * cell.seq_len * tokens)
    return base + attn


def compulsory_hbm_bytes_per_chip(cfg: ModelConfig, cell: ShapeCell,
                                  chips: int, accum: int) -> float:
    """Minimal HBM traffic per chip per step (roofline memory floor).

    train:   weights read fwd+bwd per microbatch (sharded across chips) +
             grads/opt state read+write + saved residual stream per layer
    prefill: weights once + KV cache write + activations streamed
    decode:  weights once + KV cache read (the dominant term) + write of 1
    """
    el = jnp.dtype(cfg.dtype).itemsize
    pbytes = cfg.param_count() * el
    n_layers = max(cfg.num_layers, 1)
    d = cfg.d_model
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        weights = pbytes * 2 * accum          # fwd + bwd read per microbatch
        optim = pbytes * 2 + cfg.param_count() * 4 * 2 * 2   # grad + m/v rw
        resid = tokens * d * el * n_layers * 2               # save + reload
        total = weights + optim + resid
        return total / chips
    kv_per_tok = cfg.kv_bytes_per_token(el)
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        weights = pbytes
        kv_write = tokens * kv_per_tok
        resid = tokens * d * el * n_layers
        return (weights + kv_write + resid) / chips
    # decode
    kv_read = cell.global_batch * cell.seq_len * kv_per_tok
    ssm = cell.global_batch * cfg.ssm_state_bytes() * 2
    weights = pbytes
    return (weights + kv_read + ssm) / chips
