"""Serving launcher: runs the PAPI engine against a synthetic request trace.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
        --requests 16 --spec-len 3 --alpha 6

Prints per-iteration scheduler decisions (RLP, TLP, AI estimate, chosen FC
path) — the runtime view of Figure 5(d).

Prompts of any length are served: admission chunks prompts longer than the
compiled 32-token prefill window through `models.prefill_chunk` (KV written
at running offsets, first output token from the final chunk), so the trace's
long-prompt tail is no longer truncated.  A prompt the KV budget cannot hold
at all is rejected honestly and reported.

Mesh serving (§5.3): ``--mesh dp,tp`` builds a (data, model) mesh and runs
the engine sharded — FC weights split one FC-PIM bank per `model` shard, KV
cache sliced one Attn-PIM unit per shard.  On a CPU host the launcher forces
dp*tp host devices automatically, so

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
        --requests 16 --mesh 1,8

runs the full 8-way tensor-parallel engine on one machine (token streams
are identical to the 1-device run — greedy argmax is invariant to the
partitioning's ulp-level logit shifts).  ``--attn-pim`` additionally routes
EVERY decode-path attention through the Pallas flash-decode kernel: plain
decode, the TLP>1 verify windows ``--spec-len`` produces (the windowed
kernel applies the intra-window causal mask), and chunked-prefill waves —
so the flag composes with speculative decoding and ``--kv paged`` instead
of silently reverting to the XLA path outside plain decode.

``--kv paged`` switches the KV cache to the Attn-PIM bank-row layout:
pooled fixed-size pages + per-slot block tables, page-budgeted admission
(a request enters iff pages for prompt + max_new + spec window are
available) — per-request context is bounded by the pool, not a uniform
slot.  Token streams are identical to ``--kv dense`` on any workload both
layouts can hold.  Composes with ``--attn-pim`` (block-table Pallas
kernel) and ``--mesh`` (KV-head-sharded paged pools).

Failure model: ``--deadline S`` bounds every request's wall clock (expired
requests finish with ``finished_reason="timeout"`` and their tokens-so-far),
and ``--fault kind[:prob]`` (repeatable; ``--fault-seed``) injects a
deterministic schedule of admission failures / NaN logits / kernel
corruption / step latency / engine crashes to exercise the engine's
graceful-degradation paths — see docs/ARCHITECTURE.md, "Failure model &
graceful degradation".

Durability: ``--journal PATH`` write-ahead-journals every submit / admit /
token commit / finish to PATH (append-only, checksummed records), and
``--resume PATH`` cold-starts the engine from a journal or snapshot left
by a crashed run — every unfinished request re-admits as
``prompt + committed-tokens`` and its stream continues bit-identically
(deadlines resume with their remaining budget).  Crash one run with
``--journal wal.j --fault crash:0.05``, then recover it with
``--journal wal.j --resume wal.j``.

Continuous batching: ``--arrivals RATE`` turns the trace into a LIVE
Poisson arrival stream served by `PapiEngine.serve` — requests are admitted
as they arrive, their prompt chunks ride the SAME device waves as running
decodes (no prefill stall), tokens stream as they commit, and the launcher
reports per-request queue delay / TTFT / TPOT plus p50/p99 aggregates.
Composes with every flag above (--kv paged, --spec-len, --mesh, --fault,
--deadline).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=6.0)
    ap.add_argument("--spec-len", type=int, default=1)
    ap.add_argument("--draft-arch", default=None)
    ap.add_argument("--task", default="general-qa")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="run mesh-sharded, e.g. '1,8' = 8-way tensor "
                         "parallel (FC-PIM banks / Attn-PIM KV shards)")
    ap.add_argument("--attn-pim", action="store_true",
                    help="decode attention through the Pallas flash-decode "
                         "kernel — plain decode, speculative verify "
                         "windows, and chunked-prefill waves alike "
                         "(sharded per KV shard under --mesh; block-table "
                         "kernel under --kv paged)")
    ap.add_argument("--kv", choices=("dense", "paged"), default="dense",
                    help="KV-cache layout: 'dense' per-slot slabs, or "
                         "'paged' Attn-PIM bank-row pages with block tables "
                         "and page-budgeted admission (long contexts share "
                         "one pooled budget instead of uniform slots)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--kv paged; one Attn-PIM "
                         "bank row)")
    ap.add_argument("--max-blocks", type=int, default=None,
                    help="block-table width (--kv paged): caps per-request "
                         "context at max_blocks*page_size tokens and bounds "
                         "the XLA oracle path's gathered KV view (the "
                         "--attn-pim kernel never gathers); default = "
                         "the whole pool")
    ap.add_argument("--deadline", type=float, default=None, metavar="SECONDS",
                    help="per-request wall-clock budget from submit(); an "
                         "expired request finishes honestly with "
                         "finished_reason='timeout' and its tokens-so-far")
    ap.add_argument("--fault", action="append", default=[],
                    metavar="KIND[:PROB]",
                    help="inject a deterministic fault schedule (repeatable): "
                         "kinds admit / nan / kernel / latency / crash, "
                         "per-iteration probability PROB (default 1.0).  "
                         "E.g. '--fault nan:0.2 --fault admit:0.5'.  The "
                         "engine degrades gracefully instead of emitting "
                         "garbage ('crash' kills it mid-trace — recover "
                         "with --journal + --resume) — see "
                         "docs/ARCHITECTURE.md, 'Failure model'")
    ap.add_argument("--sanitize", action="store_true",
                    help="run under the tracing-discipline sanitizer "
                         "(repro.debug.sanitize): transfer-guard around "
                         "every step, rank-promotion-raise, a hard "
                         "one-transfer-per-steady-iteration budget, and "
                         "a zero-retrace compile census — aborts on the "
                         "first violated invariant")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the fault schedule (a pure function of "
                         "(seed, iteration), so runs replay exactly)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the engine's typed event trace and write "
                         "it to PATH on exit — iteration spans, scheduler "
                         "decisions (AI estimate vs alpha), per-program "
                         "timings by jit-cache key, preemptions/deferrals/"
                         "faults, page-pool occupancy.  Summarize with "
                         "tools/trace_report.py")
    ap.add_argument("--trace-format", choices=("chrome", "jsonl"),
                    default="chrome",
                    help="trace serialization: 'chrome' opens in Perfetto / "
                         "chrome://tracing (one lane per slot + scheduler + "
                         "pool + programs), 'jsonl' is the raw typed events")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text-exposition snapshot of "
                         "the papi_engine_* counters/gauges on exit "
                         "(implies tracing even without --trace)")
    ap.add_argument("--log-level", default=None,
                    metavar="DEBUG|INFO|WARNING|ERROR",
                    help="wire the 'repro.serving' logger to stderr at this "
                         "level (deferral=DEBUG, preemption/unhappy "
                         "finishes=INFO, degraded steps=WARNING, "
                         "stalls=ERROR)")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="write-ahead request journal: append-only "
                         "checksummed records (submit/admit/token-commit/"
                         "finish/cancel/preempt) to PATH, torn tail "
                         "auto-truncated on reopen; a crashed run recovers "
                         "with --resume PATH and its streams continue "
                         "bit-identically")
    ap.add_argument("--resume", default=None, metavar="PATH",
                    help="cold-start recovery: re-admit every unfinished "
                         "request from the journal or engine snapshot at "
                         "PATH (finished requests are never re-run; "
                         "deadlines keep their remaining budget) and serve "
                         "them instead of generating a fresh trace")
    ap.add_argument("--arrivals", type=float, default=None, metavar="RATE",
                    help="continuous-batching mode: the trace arrives LIVE "
                         "as a seeded Poisson process (RATE requests per "
                         "iteration expected) streaming through "
                         "PapiEngine.serve() — new prompts chunk-prefill in "
                         "the same waves as running decodes; prints "
                         "per-request queue-delay/TTFT/TPOT and the "
                         "p50/p99 latency summary")
    args = ap.parse_args()

    if args.log_level:
        import logging
        logging.basicConfig(
            level=getattr(logging, args.log_level.upper()),
            format="%(asctime)s %(levelname)-7s %(name)s: %(message)s")

    # Mesh sizing must happen before the first jax backend touch, hence the
    # deferred repro/jax imports below.
    from repro.launch.mesh import force_host_device_count, parse_mesh
    mesh_shape = parse_mesh(args.mesh) if args.mesh else None
    if mesh_shape is not None:
        force_host_device_count(mesh_shape[0] * mesh_shape[1])

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.traces import generate_trace
    from repro.launch.mesh import make_serving_mesh
    from repro.models import init_params
    from repro.serving import (EngineCrashError, PapiEngine, ServeRequest,
                               Tracer, export_prometheus, parse_fault_specs,
                               write_trace)

    mesh = None
    if mesh_shape is not None:
        dp, tp = mesh_shape
        n = len(jax.devices())
        if n < dp * tp:
            raise SystemExit(
                f"--mesh {dp},{tp} needs {dp * tp} devices, have {n} "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{dp * tp} before launch)")
        mesh = make_serving_mesh(dp, tp)
        print(f"mesh: {dict(mesh.shape)} over {dp * tp} of {n} "
              f"{jax.default_backend()} devices")

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    draft = None
    if args.draft_arch:
        dcfg = get_config(args.draft_arch)
        draft = (dcfg, init_params(dcfg, jax.random.PRNGKey(args.seed + 1)))

    tracer = (Tracer() if (args.trace or args.metrics_out) else None)
    eng = PapiEngine(
        cfg, params, max_slots=args.max_slots, cache_capacity=256,
        prefill_len=32, alpha=args.alpha, spec_len=args.spec_len,
        draft=draft, mesh=mesh, attn_pim=args.attn_pim,
        kv_layout=args.kv, page_size=args.page_size,
        max_blocks=args.max_blocks,
        faults=parse_fault_specs(args.fault, seed=args.fault_seed),
        tracer=tracer, sanitize=args.sanitize, journal=args.journal,
    )
    if args.resume:
        info = eng.restore(args.resume)
        print(f"resumed {info['resumed']} unfinished request(s) from "
              f"{args.resume} ({info['finished']} already finished"
              + (f", {info['torn_bytes']} torn byte(s) discarded"
                 if info["torn_bytes"] else "") + ")")
    rng = np.random.default_rng(args.seed)
    # Prompts are no longer clamped to the prefill window — admission chunks
    # any prompt through it (32 tokens/wave here).  The cap below only keeps
    # the synthetic trace inside the dense slab budget (capacity 256 minus
    # the 64-token output cap and the speculative window); `--kv paged`
    # serves the same lengths from the pooled pages.
    max_prompt = 256 - 64 - max(args.spec_len, 1) - 1
    reqs = []
    if not args.resume:
        # a resumed run serves the recovered queue only: the crashed run
        # already journaled this trace's submits, and re-generating it
        # would collide with the recovered req_ids
        for i, req in enumerate(generate_trace(args.task, args.requests,
                                               args.seed)):
            prompt = rng.integers(3, cfg.vocab_size,
                                  size=min(req.input_len, max_prompt))
            reqs.append(ServeRequest(i, prompt.tolist(),
                                     max_new_tokens=min(req.output_len, 64),
                                     deadline_s=args.deadline))

    try:
        results = _run_trace(args, eng, reqs, rng)
    except EngineCrashError as exc:
        print(f"\nengine crashed (injected) at iteration {exc.iteration}"
              + (f"; recover with --resume {args.journal}" if args.journal
                 else " — run with --journal PATH to make crashes "
                      "recoverable"))
        raise SystemExit(1)
    _report(args, eng, results, tracer)


def _run_trace(args, eng, reqs, rng) -> list:
    import numpy as np

    from repro.serving import ServeRequest

    if args.arrivals is not None:
        # live mode: Poisson arrivals on the iteration clock, streamed
        # through the continuous-batching serve loop (a resumed run has an
        # empty arrival schedule — serve() just drains the recovered queue)
        from repro.serving import latency_summary
        sched: list[list[ServeRequest]] = [[]]
        if reqs:
            arrive = np.cumsum(np.floor(
                rng.exponential(1.0 / max(args.arrivals, 1e-9),
                                len(reqs))).astype(int))
            sched = [[] for _ in range(int(arrive[-1]) + 1)]
            for r, it in zip(reqs, arrive):
                sched[int(it)].append(r)
        results = []
        streamed = 0
        for ev in eng.serve(sched, max_iterations=2000):
            if not ev.finished:
                streamed += 1
                continue
            res = ev.result
            results.append(res)
            line = (f"req {res.req_id:3d}: {len(res.tokens):3d} tokens "
                    f"({res.finished_reason}), queue "
                    f"{res.queue_delay_iters} iters, ttft "
                    f"{res.ttft_iters} iters")
            if res.ttft_s is not None:
                line += f" / {res.ttft_s * 1e3:.0f}ms"
            if res.tpot_s is not None:
                line += f", tpot {res.tpot_s * 1e3:.1f}ms"
            print(line)
        summ = latency_summary(results)
        print(f"\nstreamed {streamed} tokens live over "
              f"{summ['n']} requests; latency percentiles:")
        for field in ("queue_delay_iters", "ttft_iters", "ttft_s", "tpot_s"):
            st = summ.get(field)
            if st is not None:
                unit = "iters" if field.endswith("iters") else "s"
                print(f"  {field:17s} p50 {st['p50']:9.3f}  "
                      f"p99 {st['p99']:9.3f}  ({unit})")
        return results
    for r in reqs:
        eng.submit(r)
    return eng.run(max_iterations=2000)


def _report(args, eng, results, tracer) -> None:
    by_reason: dict[str, int] = {}
    for r in results:
        by_reason[r.finished_reason] = by_reason.get(r.finished_reason, 0) + 1
    unhappy = sum(by_reason.get(k, 0)
                  for k in ("rejected", "timeout", "cancelled", "aborted"))
    print(f"\ncompleted {len(results) - unhappy} requests in "
          f"{eng.iteration} iterations"
          + (f" (unhappy: { {k: v for k, v in sorted(by_reason.items()) if k not in ('eos', 'length')} })"
             if unhappy else ""))
    if eng.preemptions or eng.degraded_steps or args.fault:
        fired = (dict(eng.faults.counts) if eng.faults is not None else {})
        print(f"resilience: {eng.preemptions} preemptions, "
              f"{eng.degraded_steps} degraded steps, faults fired {fired}")
    tok = sum(len(r.tokens) for r in results)
    wall = sum(s.wall_s for s in eng.stats)
    print(f"tokens: {tok}  wall: {wall:.2f}s  tok/s: {tok / max(wall, 1e-9):.1f}")
    print(f"reschedules: {eng.scheduler.num_reschedules}")
    rep = eng.sanitize_report()
    if rep is not None:
        print(f"sanitize: {rep.steady_iterations}/{rep.iterations} steady "
              f"iterations at {rep.transfers_per_steady_iter:.2f} "
              f"transfers/iter (budget {rep.transfer_budget}), "
              f"{rep.programs} programs, {rep.recompiles} steady-state "
              "recompiles")
    if eng.kv is not None:
        st = eng.kv.stats()
        frag = max((s.kv_fragmentation for s in eng.stats), default=0.0)
        print(f"kv pages: watermark {st.watermark}/{st.num_pages} "
              f"({st.page_size} tokens/page), peak fragmentation "
              f"{frag:.1%}")
    print("\niter  rlp tlp    AI  fc_path  new_toks")
    for s in eng.stats[:: max(len(eng.stats) // 20, 1)]:
        print(f"{s.iteration:5d} {s.rlp:4d} {s.tlp:3d} {s.ai_estimate:5.1f}  "
              f"{s.fc_variant:7s} {s.new_tokens:5d}")

    if tracer is not None:
        if args.trace:
            write_trace(tracer, args.trace, args.trace_format)
        if args.metrics_out:
            from pathlib import Path
            Path(args.metrics_out).write_text(export_prometheus(tracer))
        c = tracer.counters
        prog_s = sum(t.total_s for t in tracer.programs.values())
        print(f"\ntelemetry: {tracer.emitted} events "
              f"({tracer.dropped} dropped), {c.get('scheduler_flip', 0)} "
              f"scheduler flips, {len(tracer.programs)} program keys "
              f"({prog_s:.2f}s on device)"
              + (f" -> {args.trace}" if args.trace else "")
              + (f", metrics -> {args.metrics_out}"
                 if args.metrics_out else ""))
        if args.trace:
            print(f"  summarize: python tools/trace_report.py {args.trace}")


if __name__ == "__main__":
    main()
