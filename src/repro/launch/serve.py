"""Serving launcher: runs the PAPI engine against a synthetic request trace.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
        --requests 16 --spec-len 3 --alpha 6

Prints per-iteration scheduler decisions (RLP, TLP, AI estimate, chosen FC
path) — the runtime view of Figure 5(d).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.traces import generate_trace
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=6.0)
    ap.add_argument("--spec-len", type=int, default=1)
    ap.add_argument("--draft-arch", default=None)
    ap.add_argument("--task", default="general-qa")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    draft = None
    if args.draft_arch:
        dcfg = get_config(args.draft_arch)
        draft = (dcfg, init_params(dcfg, jax.random.PRNGKey(args.seed + 1)))

    eng = PapiEngine(
        cfg, params, max_slots=args.max_slots, cache_capacity=256,
        prefill_len=32, alpha=args.alpha, spec_len=args.spec_len,
        draft=draft,
    )
    rng = np.random.default_rng(args.seed)
    for i, req in enumerate(generate_trace(args.task, args.requests,
                                           args.seed)):
        prompt = rng.integers(3, cfg.vocab_size, size=min(req.input_len, 32))
        eng.submit(ServeRequest(i, prompt.tolist(),
                                max_new_tokens=min(req.output_len, 64)))

    results = eng.run(max_iterations=2000)
    print(f"\ncompleted {len(results)} requests in {eng.iteration} iterations")
    tok = sum(len(r.tokens) for r in results)
    wall = sum(s.wall_s for s in eng.stats)
    print(f"tokens: {tok}  wall: {wall:.2f}s  tok/s: {tok / max(wall, 1e-9):.1f}")
    print(f"reschedules: {eng.scheduler.num_reschedules}")
    print("\niter  rlp tlp    AI  fc_path  new_toks")
    for s in eng.stats[:: max(len(eng.stats) // 20, 1)]:
        print(f"{s.iteration:5d} {s.rlp:4d} {s.tlp:3d} {s.ai_estimate:5.1f}  "
              f"{s.fc_variant:7s} {s.new_tokens:5d}")


if __name__ == "__main__":
    main()
