"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b-smoke \
        --steps 100 [--resume] [--accum 2] [--compress-grads]

On a real TPU pod this binary runs per-host under `jax.distributed` (the
mesh comes from `make_production_mesh`); on CPU it trains reduced configs on
the host mesh — same code path, same checkpoints, same data stream.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.training import AdamWConfig, TrainConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    tcfg = TrainConfig(
        steps=args.steps, accum=args.accum, remat=not args.no_remat,
        compress_grads=args.compress_grads,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
    )
    dcfg = DataConfig(batch=args.batch, seq_len=args.seq_len)
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    res = run_training(cfg, tcfg, dcfg, ocfg, resume=args.resume)
    print(f"done: {res.final_step} steps, final loss "
          f"{res.losses[-1]:.4f}, stragglers {res.straggler_events}, "
          f"resumed_from={res.resumed_from}")


if __name__ == "__main__":
    main()
