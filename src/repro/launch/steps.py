"""Builds the jit-able step functions + sharding trees for each shape cell.

This is the bridge between the model code (logical axis annotations) and a
concrete mesh: it picks the rule table (memory-napkin-math driven), resolves
param/opt/cache/batch shardings, and returns everything `dryrun.py`,
`train.py` and `serve.py` need to lower.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.launch.specs import input_specs
from repro.models import (
    cache_logical_axes,
    decode_step,
    forward_train,
    param_logical_axes,
    param_shapes,
    prefill,
)
from repro.training.optim import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.training.train_loop import make_train_step

PyTree = Any

HBM_PER_CHIP = 16e9          # v5e
# Switch decode to 2D weight-stationary sharding (and prefill to FSDP) when
# the TP-only weight share exceeds this: 6 GB leaves room for deepseek-67b's
# 95-layer KV cache next to its weights (§Perf iteration 6b).
WEIGHT_FSDP_THRESHOLD = 6e9


def param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * jnp.dtype(cfg.dtype).itemsize


def choose_rules(cfg: ModelConfig, cell: ShapeCell, mesh) -> dict:
    """Pick the logical->mesh rule table for this cell.

    Training always runs FSDP (ZeRO-3-style weight sharding over data).
    Inference keeps weights TP-resident unless the per-chip TP share alone
    blows the HBM budget (command-r-plus-104b: 208 GB / 16 = 13 GB -> FSDP).
    """
    multi_pod = "pod" in mesh.axis_names
    if cell.kind == "train":
        # Universal SP + unconstrained FFN intermediates measured best on
        # every arch family — including indivisible-head archs, where
        # dropping SP was tried and REFUTED (EXPERIMENTS.md §Perf iter 4:
        # it idles the model axis or regresses the dW strategy).
        return shd.train_rules(multi_pod=multi_pod, fsdp=True)
    model_shards = mesh.shape["model"]
    need_fsdp = param_bytes(cfg) / model_shards > WEIGHT_FSDP_THRESHOLD
    rules = shd.serve_rules(multi_pod=multi_pod,
                            long_context=(cell.seq_len >= 262_144))
    if need_fsdp and cell.kind == "decode":
        # 2D weight-STATIONARY decode (§Perf iteration 5).  Naive FSDP
        # re-gathers every weight per decoded token (~param_bytes of wire per
        # step).  Instead: replicate the tiny decode activations (frees the
        # data axis), shard weights 2D over (data x model) and contract
        # in-place — per-layer output all-reduces are activation-sized
        # (MBs), a ~24x collective reduction for command-r-plus-104b.
        data = ("pod", "data") if multi_pod else "data"
        rules["fsdp"] = data
        rules["batch"] = None
        rules["act_kv_seq"] = (data, "model") if not multi_pod else (
            "pod", "data", "model")
        if multi_pod:
            rules["act_kv_seq"] = ("pod", "data", "model")
    elif need_fsdp:
        rules["fsdp"] = ("pod", "data") if multi_pod else "data"
    return rules


def _batch_logical(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Logical axes for each batch-spec leaf."""
    lead = ("scan",) if cell.kind == "train" else ()  # accum axis unsharded

    def t(*ax):
        return lead + ax if cell.kind == "train" else ax

    # seq dims of token/target leaves share the residual stream's "seq"
    # sharding (SP): keeps cross-entropy's take_along_axis aligned with the
    # seq-sharded logits instead of provoking a full logits all-gather.
    common: dict[str, tuple] = {}
    if cfg.family == "audio":
        common = {"frames": t("batch", "seq", None), "mask": t("batch", "seq"),
                  "targets": t("batch", "seq"),
                  "target_mask": t("batch", "seq")}
    elif cfg.family == "vlm":
        common = {"tokens": t("batch", None),
                  "patch_embeds": t("batch", None, None),
                  "positions": t("batch", None, None),
                  "targets": t("batch", None)}
    else:
        common = {"tokens": t("batch", "seq"), "targets": t("batch", "seq")}
    common["prompt_lens"] = ("batch",)
    return common


@dataclasses.dataclass
class BuiltStep:
    fn: Callable                 # jit-able python callable
    args: tuple                  # ShapeDtypeStruct pytrees, in order
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    rules: dict
    accum: int
    kind: str


def build_step(cfg: ModelConfig, cell: ShapeCell, mesh) -> BuiltStep:
    rules = choose_rules(cfg, cell, mesh)
    data_shards = mesh.shape["data"] * mesh.shape.get("pod", 1)
    batch_specs, cache_sp, accum = input_specs(cfg, cell, data_shards)

    p_shapes = param_shapes(cfg)
    p_axes = param_logical_axes(cfg)
    p_shard = shd.tree_shardings(p_axes, p_shapes, rules, mesh)

    blog = _batch_logical(cfg, cell)
    b_shard = {
        k: shd.tree_shardings(blog[k], v, rules, mesh)
        for k, v in batch_specs.items()
    }

    if cell.kind == "train":
        ocfg = AdamWConfig()
        opt_specs = jax.eval_shape(init_adamw, p_shapes)
        # ZeRO-1 comes for free here: fsdp rules already shard states.
        o_axes = AdamWState(
            step=(),
            m=p_axes,
            v=p_axes,
        )
        o_shard = AdamWState(
            step=jax.sharding.NamedSharding(mesh, shd.P()),
            m=shd.tree_shardings(p_axes, opt_specs.m, rules, mesh),
            v=shd.tree_shardings(p_axes, opt_specs.v, rules, mesh),
        )
        raw_step = make_train_step(cfg, ocfg, accum=accum, remat=True)

        def fn(params, opt_state, batch):
            with shd.axis_rules(rules, mesh):
                new_p, new_o, _, metrics = raw_step(params, opt_state, {}, batch)
            return new_p, new_o, metrics["loss"]

        return BuiltStep(
            fn=fn,
            args=(p_shapes, opt_specs, batch_specs),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard,
                           jax.sharding.NamedSharding(mesh, shd.P())),
            donate_argnums=(0, 1),
            rules=rules, accum=accum, kind="train",
        )

    c_axes = cache_logical_axes(cfg)
    c_shard = shd.tree_shardings(c_axes, cache_sp, rules, mesh)

    if cell.kind == "prefill":
        def fn(params, batch, cache):
            with shd.axis_rules(rules, mesh):
                return prefill(cfg, params, batch, cache)

        repl = jax.sharding.NamedSharding(mesh, shd.P())
        logits_shard = jax.sharding.NamedSharding(
            mesh, shd.filter_spec_for_shape(
                shd.P(rules.get("batch"), rules.get("vocab")),
                (cell.global_batch, cfg.vocab_size), mesh))
        return BuiltStep(
            fn=fn,
            args=(p_shapes, batch_specs, cache_sp),
            in_shardings=(p_shard, b_shard, c_shard),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(2,),
            rules=rules, accum=1, kind="prefill",
        )

    # decode: serve_step(params, cache, tokens) -> (logits, cache)
    def fn(params, cache, tokens, positions=None):
        with shd.axis_rules(rules, mesh):
            return decode_step(cfg, params, cache, tokens, positions)

    b = cell.global_batch
    tok_shard = jax.sharding.NamedSharding(
        mesh, shd.filter_spec_for_shape(
            shd.P(rules.get("batch"), None), (b, 1), mesh))
    logits_shard = jax.sharding.NamedSharding(
        mesh, shd.filter_spec_for_shape(
            shd.P(rules.get("batch"), None, rules.get("vocab")),
            (b, 1, cfg.vocab_size), mesh))
    args = [p_shapes, cache_sp, batch_specs["tokens"]]
    in_sh = [p_shard, c_shard, tok_shard]
    if "positions" in batch_specs:
        args.append(batch_specs["positions"])
        in_sh.append(jax.sharding.NamedSharding(
            mesh, shd.filter_spec_for_shape(
                shd.P(rules.get("batch"), None, None), (b, 3, 1), mesh)))
    return BuiltStep(
        fn=fn,
        args=tuple(args),
        in_shardings=tuple(in_sh),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,),
        rules=rules, accum=1, kind="decode",
    )


def lower_step(built: BuiltStep, mesh):
    """jit + lower under the mesh.  Returns the Lowered object."""
    jitted = jax.jit(
        built.fn,
        in_shardings=built.in_shardings,
        out_shardings=built.out_shardings,
        donate_argnums=built.donate_argnums,
    )
    with mesh:
        return jitted.lower(*built.args)
