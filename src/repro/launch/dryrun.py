import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production meshes, prove memory/sharding coherence, and
extract the roofline terms.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the dry-run needs 512 placeholder
host devices to build the (2, 16, 16) production mesh.  Nothing else in the
repo sets this flag (smoke tests and benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED, SHAPES, applicable_shapes, get_config
from repro.launch.hlo import HLOAnalysis
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    Roofline,
    compulsory_hbm_bytes_per_chip,
    model_flops,
)
from repro.launch.steps import build_step, lower_step


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:          # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes"):
        if hasattr(ma, key):
            out[key] = int(getattr(ma, key))
    if out:
        # arguments + temps - donated aliases = live bytes per device
        out["live_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str | None,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)

    t0 = time.time()
    built = build_step(cfg, cell, mesh)
    lowered = lower_step(built, mesh)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _memory_analysis_dict(compiled)
    try:
        cost = compiled.cost_analysis()
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        cost = {"error": str(e)}

    hlo = HLOAnalysis(compiled.as_text(), num_devices=chips)
    summary = hlo.summary()
    # post-SPMD HLO shapes are per-partition: scale to global FLOPs so that
    # replicated (unsharded) compute shows up as redundancy in the ratio.
    summary["flops"] = summary["flops"] * chips

    mf = model_flops(cfg, cell)
    rl = Roofline(
        arch=arch, cell=shape, mesh=mesh_name, chips=chips,
        hlo_flops=summary["flops"],
        model_flops=mf,
        hbm_bytes_per_chip=compulsory_hbm_bytes_per_chip(
            cfg, cell, chips, built.accum),
        wire_bytes_per_chip=summary["collective_wire_bytes_per_device"],
        memory_residency_per_chip=mem.get("live_bytes_per_device"),
    )

    record = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "kind": built.kind, "accum": built.accum,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "xla_cost_analysis": {k: cost[k] for k in ("flops", "bytes accessed")
                              if k in cost},
        "hlo": summary,
        "collective_sites": hlo.collective_sites(8),
        "roofline": rl.row(),
    }
    if verbose:
        ma = mem.get("live_bytes_per_device")
        print(f"[dryrun] {arch:24s} {shape:12s} mesh={mesh_name:10s} "
              f"OK  compile={t_compile:6.1f}s "
              f"live/dev={ma/1e9 if ma else float('nan'):6.2f}GB "
              f"bottleneck={rl.bottleneck:10s} "
              f"roofline_frac={rl.roofline_fraction:.3f}")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"  hlo: flops={summary['flops']:.3e} "
              f"wire_bytes/dev={summary['collective_wire_bytes_per_device']:.3e} "
              f"{summary['collective_breakdown']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape}__{mesh_name}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    if args.all:
        for cfg in ASSIGNED:
            for shape in applicable_shapes(cfg):
                for mp in meshes:
                    cells.append((cfg.name, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = []
    for arch, shape, mp in cells:
        try:
            run_cell(arch, shape, mp, args.out)
        except Exception:
            failures.append((arch, shape, mp))
            print(f"[dryrun] {arch} {shape} multi_pod={mp} FAILED")
            traceback.print_exc()
    print(f"\n[dryrun] {len(cells) - len(failures)}/{len(cells)} cells passed")
    for f in failures:
        print("  FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
