"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* first jax init.
"""
from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) = (pod, data, model) = 512 chips; the `pod` axis
    carries data parallelism across the DCN/ICI-superpod boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices this host actually has, as a 1D data mesh — used by
    the runnable examples on CPU."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_serving_mesh(dp: int = 1, tp: int = 1):
    """The serving engine's (data, model) mesh.  `model` is the tensor axis:
    FC weights split into one FC-PIM bank per shard and the KV cache slices
    one Attn-PIM unit per shard (§5.3); `data` replicates the engine for
    throughput.  Uses the first dp*tp devices."""
    return jax.make_mesh((dp, tp), ("data", "model"), devices=jax.devices()[: dp * tp])


def parse_mesh(spec: str) -> tuple[int, int]:
    """Parse a ``--mesh dp,tp`` CLI value into (dp, tp)."""
    parts = spec.split(",")
    if len(parts) != 2:
        raise ValueError(f"--mesh wants 'dp,tp', got {spec!r}")
    dp, tp = (int(p) for p in parts)
    if dp < 1 or tp < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    return dp, tp


def force_host_device_count(n: int) -> None:
    """Ask XLA's CPU backend for `n` host devices.  Only effective BEFORE the
    first jax backend touch (importing jax is fine; creating an array is
    not), so launchers call this right after argument parsing.  A count
    already forced via XLA_FLAGS is left alone."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()
