"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) = (pod, data, model) = 512 chips; the `pod` axis
    carries data parallelism across the DCN/ICI-superpod boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices this host actually has, as a 1D data mesh — used by
    the runnable examples on CPU."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
