"""The paper's own evaluation models (PAPI §7.1) plus OPT-30B (§3.1 roofline).

These drive the reproduction benchmarks (core/system simulators, Figs. 2-12).
They are also full `ModelConfig`s so they can be lowered/served like any
assigned arch if desired.
"""
from repro.configs.base import ModelConfig

# LLaMA-65B [arXiv:2302.13971]
LLAMA_65B = ModelConfig(
    name="llama-65b",
    family="dense",
    num_layers=80,
    d_model=8_192,
    num_heads=64,
    num_kv_heads=64,       # LLaMA-1: full MHA
    d_ff=22_016,
    vocab_size=32_000,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
)

# GPT-3 66B: the paper's "GPT-3 66B" matches the GPT-3 family scaling row
# (66B ~ h=9216, 64 layers, 72 heads) [arXiv:2005.14165 table 2.1 interp.]
GPT3_66B = ModelConfig(
    name="gpt3-66b",
    family="dense",
    num_layers=64,
    d_model=9_216,
    num_heads=72,
    num_kv_heads=72,
    d_ff=36_864,           # 4h
    vocab_size=50_257,
    head_dim=128,
    qkv_bias=True,
    mlp="gelu",
    norm="layernorm",
)

# GPT-3 175B [arXiv:2005.14165]
GPT3_175B = ModelConfig(
    name="gpt3-175b",
    family="dense",
    num_layers=96,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=96,
    d_ff=49_152,           # 4h
    vocab_size=50_257,
    head_dim=128,
    qkv_bias=True,
    mlp="gelu",
    norm="layernorm",
)

# OPT-30B (used for the paper's Fig. 2 roofline study) [arXiv:2205.01068]
OPT_30B = ModelConfig(
    name="opt-30b",
    family="dense",
    num_layers=48,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=56,
    d_ff=28_672,           # 4h
    vocab_size=50_272,
    head_dim=128,
    qkv_bias=True,
    mlp="gelu",
    norm="layernorm",
)
