"""olmoe-1b-7b — MoE 64 experts top-8, per-expert d_ff=1024.
[arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=16,       # full MHA per assignment (kv=16)
    d_ff=0,
    vocab_size=50_304,
    head_dim=128,
    qkv_bias=False,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=64, top_k=8, d_ff=1_024),
)
