"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

38 Mamba2 backbone blocks; a single *shared* (weight-tied) attention+MLP
block is interleaved every `period` backbone blocks (zamba2's signature
design: the shared block re-uses one set of weights at multiple depths).
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2_048,
    num_heads=32,
    num_kv_heads=32,       # shared attn block is full MHA (kv=32)
    d_ff=8_192,            # shared block MLP
    vocab_size=32_000,
    head_dim=64,
    qkv_bias=False,
    mlp="swiglu",
    norm="rmsnorm",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256),
    hybrid=HybridConfig(period=6),
    tie_embeddings=True,
)
