"""qwen2-0.5b — dense, GQA kv=2, QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_936,
    head_dim=64,           # 896 / 14
    qkv_bias=True,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,   # qwen2-0.5b ties lm_head to embeddings
)
