"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).
[arXiv:2106.07447; unverified]

Encoder-only: bidirectional attention, no KV cache, no decode step.  The
convolutional waveform frontend is a stub (`frontend="frame"`): inputs are
precomputed frame embeddings (batch, frames, d_model).  vocab_size=504 is the
masked-prediction codebook (k-means targets).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1_280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5_120,
    vocab_size=504,
    head_dim=80,
    qkv_bias=True,
    mlp="gelu",
    norm="layernorm",
    causal=False,
    decoder=False,
    frontend="frame",
)
