"""qwen2-vl-7b — VLM backbone, M-RoPE, GQA kv=4. [arXiv:2409.12191; hf]

Per the assignment, only the transformer BACKBONE is modeled; the vision
frontend is a stub (`frontend="patch"`): `input_specs()` provides precomputed
patch embeddings alongside text token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3_584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    m_rope=True,
    m_rope_sections=(16, 24, 24),   # temporal/height/width freq split of hd/2
    frontend="patch",
)
