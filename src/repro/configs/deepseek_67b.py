"""deepseek-67b — dense llama-arch, GQA kv=8, 95 layers. [arXiv:2401.02954; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8_192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=102_400,
    head_dim=128,
    qkv_bias=False,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)
