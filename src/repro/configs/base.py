"""Model/run configuration dataclasses.

Every architecture in the assignment pool is expressed as a `ModelConfig`.
The same dataclass drives:
  * parameter init + forward/train/decode steps (models/),
  * the serving engine (serving/),
  * the dry-run input specs (launch/specs.py),
  * the analytical roofline (launch/roofline.py) and the PAPI simulator
    (core/), which needs the FC/attention kernel dimensions.

Reduced ("smoke") variants are derived mechanically by `reduced()` so every
architecture family has a CPU-runnable twin with the same code path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    # Per-expert FFN hidden dim (the assignment's d_ff for MoE archs is
    # per-expert).
    d_ff: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Load-balancing aux loss weight (Switch-style).
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block configuration."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256
    # A is initialized in [-A_max, -A_min] (log-spaced), per head.
    a_min: float = 1.0
    a_max: float = 16.0

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style hybrid layout: a backbone of Mamba2 blocks with a single
    *shared* attention block applied every `period` backbone blocks."""
    period: int = 6


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int          # query heads; 0 for attention-free archs
    num_kv_heads: int       # GQA KV heads
    d_ff: int               # dense FFN hidden dim (0 for MoE: see moe.d_ff; 0 for ssm)
    vocab_size: int

    head_dim: int = 0       # 0 -> d_model // num_heads
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    # M-RoPE (qwen2-vl): positions are (temporal, height, width) triples;
    # head_dim is split into 3 frequency sections.
    m_rope: bool = False
    m_rope_sections: Sequence[int] = (16, 24, 24)
    tie_embeddings: bool = False
    causal: bool = True     # encoder-only archs set False
    decoder: bool = True    # False -> encoder-only (no KV cache / decode step)
    # Modality frontend stub: "token" (ids), "frame" (precomputed audio frame
    # embeddings), "patch" (precomputed vision patch embeddings + text ids).
    frontend: Literal["token", "frame", "patch"] = "token"

    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None

    # Training details
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def q_heads(self) -> int:
        return self.num_heads

    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads

    @property
    def group_size(self) -> int:
        if self.num_kv_heads == 0:
            return 1
        return max(self.num_heads // self.num_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_path(self) -> bool:
        """True if the arch can serve 500k-token contexts without a quadratic
        KV-cache attention (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode_step(self) -> bool:
        return self.decoder

    # ---- parameter counting (used for roofline MODEL_FLOPS and memory) ------
    def param_count(self) -> int:
        return sum(self._param_shapes_counts())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        total = self.param_count()
        if self.moe is None or self.moe.num_experts == 0:
            return total
        expert = self._moe_expert_params()
        inactive = expert * (self.moe.num_experts - self.moe.top_k)
        return total - inactive * self.num_layers

    def _moe_expert_params(self) -> int:
        m = self.moe
        assert m is not None
        # SwiGLU expert: gate + up + down
        return 3 * self.d_model * m.d_ff

    def _param_shapes_counts(self) -> list[int]:
        h, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        counts = [self.vocab_size * h]  # embed
        if not self.tie_embeddings and self.decoder:
            counts.append(self.vocab_size * h)  # lm head
        counts.append(h)  # final norm

        def attn_params() -> int:
            n = h * (self.num_heads * hd) + 2 * h * (self.num_kv_heads * hd)
            n += (self.num_heads * hd) * h  # out proj
            if self.qkv_bias:
                n += self.num_heads * hd + 2 * self.num_kv_heads * hd
            return n

        def mlp_params() -> int:
            if self.moe is not None and self.moe.num_experts:
                m = self.moe
                return m.num_experts * 3 * h * m.d_ff + h * m.num_experts
            if self.mlp == "swiglu":
                return 3 * h * self.d_ff
            return 2 * h * self.d_ff + self.d_ff + h  # gelu w/ biases

        def ssm_params() -> int:
            s = self.ssm
            assert s is not None
            di = s.d_inner(h)
            nh = s.n_heads(h)
            # in_proj -> [z, x, B, C, dt]; conv over (x, B, C); out_proj
            conv_dim = di + 2 * s.d_state * nh // (di // s.head_dim) if False else di + 2 * s.d_state
            n = h * (2 * di + 2 * s.d_state + nh)
            n += s.conv_kernel * conv_dim
            n += nh * 2  # A_log, D
            n += nh      # dt_bias
            n += di * h  # out_proj
            n += di      # gated-norm weight
            return n

        if self.family == "ssm":
            counts += [ssm_params() + h for _ in range(L)]
        elif self.family == "hybrid":
            assert self.hybrid is not None
            counts += [ssm_params() + h for _ in range(L)]
            # one shared attention block (+ its MLP), applied every `period`
            counts.append(attn_params() + 3 * h * self.d_ff + 2 * h)
        else:
            per_layer = attn_params() + mlp_params() + 2 * h
            counts += [per_layer for _ in range(L)]
        return counts

    # ---- KV / state cache sizing --------------------------------------------
    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        if self.family == "ssm":
            return 0
        hd = self.resolved_head_dim
        n_attn = self.num_attention_applications()
        return 2 * n_attn * self.num_kv_heads * hd * bytes_per_el

    def num_attention_applications(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            assert self.hybrid is not None
            return self.num_layers // self.hybrid.period
        return self.num_layers

    def ssm_state_bytes(self, bytes_per_el: int = 4) -> int:
        if self.ssm is None:
            return 0
        s = self.ssm
        nh = s.n_heads(self.d_model)
        n_ssm = self.num_layers
        return n_ssm * nh * s.head_dim * s.d_state * bytes_per_el

    # ---- reduced (smoke) twin -----------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny config of the same family for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            family=self.family,
            num_layers=min(self.num_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=256,
            head_dim=32 if self.num_heads else 0,
            qkv_bias=self.qkv_bias,
            attn_out_bias=self.attn_out_bias,
            mlp=self.mlp,
            norm=self.norm,
            norm_eps=self.norm_eps,
            rope_theta=self.rope_theta,
            m_rope=self.m_rope,
            m_rope_sections=(8, 12, 12) if self.m_rope else self.m_rope_sections,
            tie_embeddings=self.tie_embeddings,
            causal=self.causal,
            decoder=self.decoder,
            frontend=self.frontend,
            max_seq_len=1024,
            dtype="float32",
        )
        if self.num_kv_heads and self.num_heads:
            # keep GQA ratio flavor: full MHA stays MHA
            if self.num_kv_heads == self.num_heads:
                kw["num_kv_heads"] = kw["num_heads"]
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff=64,
                capacity_factor=self.moe.capacity_factor,
            )
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(
                d_state=16, head_dim=32, expand=2,
                conv_kernel=self.ssm.conv_kernel, chunk_size=32,
            )
        if self.hybrid is not None:
            kw["hybrid"] = HybridConfig(period=2)
        return ModelConfig(**kw)


# ---------------------------------------------------------------------------
# Input-shape cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells that are runnable for this arch, per the assignment rules:
    - `long_500k` only for sub-quadratic (ssm/hybrid) archs;
    - decode shapes skipped for encoder-only archs."""
    out = []
    for name, cell in SHAPES.items():
        if cell.is_decode and not cfg.has_decode_step:
            continue
        if name == "long_500k" and not cfg.has_subquadratic_path:
            continue
        out.append(name)
    return out


def skipped_shapes(cfg: ModelConfig) -> list[tuple[str, str]]:
    out = []
    for name, cell in SHAPES.items():
        if cell.is_decode and not cfg.has_decode_step:
            out.append((name, "encoder-only: no decode step"))
        elif name == "long_500k" and not cfg.has_subquadratic_path:
            out.append((name, "full attention is quadratic at 500k; "
                              "sub-quadratic path required"))
    return out


def microbatch_plan(cfg: ModelConfig, cell: ShapeCell, data_shards: int) -> tuple[int, int]:
    """(num_microbatches, per_step_batch) for training cells.

    Chosen so activation working set stays within HBM at the production mesh:
    big models accumulate gradients over more microbatches.
    """
    if cell.kind != "train":
        return 1, cell.global_batch
    approx_params = cfg.param_count()
    if approx_params > 50e9:
        accum = 8
    elif approx_params > 5e9:
        accum = 4
    elif approx_params > 1e9:
        accum = 2
    else:
        accum = 1
    # big-vocab logits dominate activation memory: bound them per microbatch
    if cfg.vocab_size >= 100_000:
        accum = max(accum, 4)
    # keep microbatch divisible by data shards
    while (cell.global_batch // accum) % data_shards and accum > 1:
        accum //= 2
    return accum, cell.global_batch // accum
