"""Architecture registry.

`get_config(name)` resolves any assigned architecture or paper model;
`ASSIGNED` lists the 10 assignment archs in assignment order.
"""
from __future__ import annotations

from repro.configs.base import (
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeCell,
    SSMConfig,
    applicable_shapes,
    microbatch_plan,
    skipped_shapes,
)
from repro.configs.command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.granite_8b import CONFIG as GRANITE_8B
from repro.configs.granite_moe_1b_a400m import CONFIG as GRANITE_MOE_1B_A400M
from repro.configs.hubert_xlarge import CONFIG as HUBERT_XLARGE
from repro.configs.mamba2_1_3b import CONFIG as MAMBA2_1_3B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.paper_models import GPT3_66B, GPT3_175B, LLAMA_65B, OPT_30B
from repro.configs.qwen2_0_5b import CONFIG as QWEN2_0_5B
from repro.configs.qwen2_vl_7b import CONFIG as QWEN2_VL_7B
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2_1_2B

ASSIGNED: tuple[ModelConfig, ...] = (
    QWEN2_0_5B,
    COMMAND_R_PLUS_104B,
    DEEPSEEK_67B,
    GRANITE_8B,
    ZAMBA2_1_2B,
    GRANITE_MOE_1B_A400M,
    OLMOE_1B_7B,
    QWEN2_VL_7B,
    HUBERT_XLARGE,
    MAMBA2_1_3B,
)

PAPER_MODELS: tuple[ModelConfig, ...] = (LLAMA_65B, GPT3_66B, GPT3_175B, OPT_30B)

_REGISTRY: dict[str, ModelConfig] = {c.name: c for c in ASSIGNED + PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    """Resolve an architecture id (or `<id>-smoke` for its reduced twin)."""
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    raise KeyError(
        f"unknown arch {name!r}; known: {sorted(_REGISTRY)}"
    )


def arch_names() -> list[str]:
    return [c.name for c in ASSIGNED]


__all__ = [
    "ASSIGNED",
    "PAPER_MODELS",
    "SHAPES",
    "HybridConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeCell",
    "applicable_shapes",
    "arch_names",
    "get_config",
    "microbatch_plan",
    "skipped_shapes",
]
