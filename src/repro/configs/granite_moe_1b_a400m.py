"""granite-moe-1b-a400m — MoE 32 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=0,                # MoE: see moe.d_ff (per-expert)
    vocab_size=49_155,
    head_dim=64,
    qkv_bias=False,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512),
    tie_embeddings=True,
)
