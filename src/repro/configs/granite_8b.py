"""granite-8b — dense llama-arch (code), GQA kv=8. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=49_152,
    head_dim=128,
    qkv_bias=False,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000_000.0,
    tie_embeddings=True,
)
