"""mamba2-1.3b — attention-free SSM (SSD / state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2_048,
    num_heads=0,           # attention-free
    num_kv_heads=0,
    d_ff=0,                # no FFN: Mamba2 block subsumes it (expand=2)
    vocab_size=50_280,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256),
    tie_embeddings=True,
)
