"""Runtime debugging aids for the serving engine (see sanitize.py)."""
from repro.debug.sanitize import (  # noqa: F401
    EngineSanitizer,
    SanitizeError,
    SanitizeReport,
    sanitized,
)
