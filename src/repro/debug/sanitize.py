"""Runtime sanitizer for the engine's tracing discipline.

The static side (tools/papilint) proves the *code* routes every
device->host sync through `PapiEngine._fetch` and keys every jit cache on
the scheduler-visible flags.  This module machine-checks the same
invariants at *runtime*:

- ``sanitized()`` wraps each engine step in
  ``jax.transfer_guard_device_to_host("disallow")`` so any un-sanctioned
  device->host copy raises on real accelerators (on the CPU backend
  device == host and the guard never fires — the transfer *counting*
  below is the check that works everywhere), plus
  ``jax.numpy_rank_promotion("raise")`` (the model's broadcasts are all
  explicit) and, opted in, ``jax.debug_nans``.
- ``EngineSanitizer.after_step`` asserts the transfer budget — a
  steady-state fused decode iteration (no admission, no prefill chunks,
  no degrade, no preemption) performs EXACTLY ``transfer_budget`` host
  transfers (the paper's "one sync per iteration" claim) — and takes a
  compile census over both jit caches: once a program key has compiled,
  a second signature for the same key is a silent steady-state retrace
  and raises ``SanitizeError``.

Wiring: ``PapiEngine(sanitize=True)`` or ``launch/serve.py --sanitize``;
the CI smoke gate runs ``benchmarks/engine_hotpath.py --sanitize`` and
check_bench verifies the recorded budget numbers.

debug-NaNs policy: enabled automatically only when the engine runs the
Pallas kernels in interpret mode (``pim_interpret=True``) AND no fault
injector is attached — injected logits faults ARE NaNs, and the
finite-logits guard must see them before debug_nans aborts the step.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax


class SanitizeError(RuntimeError):
    """A tracing-discipline invariant was violated at runtime."""


@dataclasses.dataclass
class SanitizeReport:
    """Counters accumulated by EngineSanitizer.after_step."""

    transfer_budget: int = 1
    iterations: int = 0          # steps that recorded an IterStats
    steady_iterations: int = 0   # fused decode-only steps (budget applies)
    steady_transfers: int = 0    # host transfers over those steps
    recompiles: int = 0          # stays 0 — a retrace raises instead
    programs: int = 0            # distinct jit-cache keys compiled

    @property
    def transfers_per_steady_iter(self) -> float:
        if self.steady_iterations == 0:
            return 0.0
        return self.steady_transfers / self.steady_iterations

    def asdict(self) -> dict[str, Any]:
        out = dataclasses.asdict(self)
        out["transfers_per_steady_iter"] = self.transfers_per_steady_iter
        return out


@contextlib.contextmanager
def sanitized(*, rank_promotion: str = "raise", debug_nans: bool = False):
    """Strict-mode JAX context for the decode loop.

    Device->host transfers outside an explicit allow-scope raise (real
    accelerators only — the CPU backend's device IS the host), implicit
    rank promotion raises everywhere, and NaNs raise when opted in.
    """
    with contextlib.ExitStack() as stack:
        stack.enter_context(jax.transfer_guard_device_to_host("disallow"))
        stack.enter_context(jax.numpy_rank_promotion(rank_promotion))
        if debug_nans:
            stack.enter_context(jax.debug_nans(True))
        yield


@contextlib.contextmanager
def transfer_allowed():
    """Explicit allow-scope for a sanctioned device->host sync site."""
    with jax.transfer_guard_device_to_host("allow"):
        yield


class EngineSanitizer:
    """Per-engine runtime gate: transfer budget + compile census.

    The engine calls ``scope(engine)`` around each step, wraps its one
    sanctioned ``jax.device_get`` in ``allow_transfers()``, and calls
    ``after_step(engine, stepped=...)`` when the step returns.
    """

    def __init__(self, *, transfer_budget: int = 1,
                 debug_nans: bool | None = None):
        self.report = SanitizeReport(transfer_budget=transfer_budget)
        self._debug_nans = debug_nans
        self._cache_sizes: dict[Any, int] = {}

    def scope(self, engine):
        debug_nans = self._debug_nans
        if debug_nans is None:
            debug_nans = bool(getattr(engine, "pim_interpret", False)) \
                and getattr(engine, "faults", None) is None
        return sanitized(debug_nans=debug_nans)

    def allow_transfers(self):
        return transfer_allowed()

    def after_step(self, engine, *, stepped: bool) -> None:
        # --- compile census: a second signature under an existing key is
        # a steady-state retrace (the flag flip should have produced a NEW
        # key — that's PL003's whole point)
        caches = {}
        caches.update(getattr(engine, "_decode_jit", {}))
        caches.update(getattr(engine, "_prefill_jit", {}))
        for key, fn in caches.items():
            size_fn = getattr(fn, "_cache_size", None)
            size = size_fn() if callable(size_fn) else 1
            prev = self._cache_sizes.get(key, 0)
            if size > max(prev, 1):
                raise SanitizeError(
                    f"steady-state retrace: program {key!r} now holds "
                    f"{size} compiled signatures (was {max(prev, 1)}) — "
                    "an input shape or static arg changed without a new "
                    "jit-cache key")
            self._cache_sizes[key] = max(prev, size)
        self.report.programs = len(self._cache_sizes)

        if not stepped:
            return
        st = engine.stats[-1]
        self.report.iterations += 1
        steady = (getattr(engine, "fused", False)
                  and st.admitted == 0 and st.arrivals == 0
                  and st.decode_slots > 0 and st.prefill_slots == 0
                  and st.degraded == 0 and st.preemptions == 0)
        if not steady:
            return
        self.report.steady_iterations += 1
        self.report.steady_transfers += st.transfers
        if st.transfers != self.report.transfer_budget:
            raise SanitizeError(
                f"transfer budget violated at iteration {st.iteration}: "
                f"{st.transfers} host transfer(s) in a steady-state fused "
                f"decode step (budget {self.report.transfer_budget}) — an "
                "un-batched sync crept onto the hot path")
