from repro.serving.engine import IterStats, PapiEngine, ServeRequest, ServeResult
from repro.serving.kv_pages import (BlockTables, PageAllocator, PagedKVManager,
                                    PageStats)
from repro.serving.sampler import greedy, sample

__all__ = ["BlockTables", "IterStats", "PageAllocator", "PagedKVManager",
           "PageStats", "PapiEngine", "ServeRequest", "ServeResult",
           "greedy", "sample"]
