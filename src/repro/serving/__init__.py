from repro.serving.engine import (AllocatorInvariantError, EngineStallError,
                                  IterStats, PapiEngine, ServeRequest,
                                  ServeResult)
from repro.serving.faults import FaultInjector, parse_fault_specs
from repro.serving.kv_pages import (BlockTables, PageAllocator, PagedKVManager,
                                    PageStats)
from repro.serving.sampler import greedy, sample

__all__ = ["AllocatorInvariantError", "BlockTables", "EngineStallError",
           "FaultInjector", "IterStats", "PageAllocator", "PagedKVManager",
           "PageStats", "PapiEngine", "ServeRequest", "ServeResult",
           "greedy", "parse_fault_specs", "sample"]
