from repro.serving.engine import IterStats, PapiEngine, ServeRequest, ServeResult
from repro.serving.sampler import greedy, sample

__all__ = ["IterStats", "PapiEngine", "ServeRequest", "ServeResult",
           "greedy", "sample"]
