from repro.serving.engine import (AllocatorInvariantError, EngineCrashError,
                                  EngineStallError, IterStats, PapiEngine,
                                  ServeRequest, ServeResult, TokenEvent)
from repro.serving.faults import FaultInjector, parse_fault_specs
from repro.serving.journal import (FinishedRequest, Journal, RecoveredRequest,
                                   RecoveredState, read_records, recover,
                                   replay, write_snapshot)
from repro.serving.kv_pages import (BlockTables, PageAllocator, PagedKVManager,
                                    PageStats)
from repro.serving.metrics import latency_summary, percentile
from repro.serving.sampler import greedy, sample
from repro.serving.telemetry import (NULL_TRACER, Event, NullTracer,
                                     ProgramTiming, Tracer, export_chrome,
                                     export_jsonl, export_prometheus,
                                     write_trace)

__all__ = ["AllocatorInvariantError", "BlockTables", "EngineCrashError",
           "EngineStallError", "Event", "FaultInjector", "FinishedRequest",
           "IterStats", "Journal", "NULL_TRACER", "NullTracer",
           "PageAllocator", "PagedKVManager", "PageStats", "PapiEngine",
           "ProgramTiming", "RecoveredRequest", "RecoveredState",
           "ServeRequest", "ServeResult", "TokenEvent", "Tracer",
           "export_chrome", "export_jsonl", "export_prometheus", "greedy",
           "latency_summary", "parse_fault_specs", "percentile",
           "read_records", "recover", "replay", "sample", "write_snapshot",
           "write_trace"]
