from repro.serving.engine import (AllocatorInvariantError, EngineStallError,
                                  IterStats, PapiEngine, ServeRequest,
                                  ServeResult, TokenEvent)
from repro.serving.faults import FaultInjector, parse_fault_specs
from repro.serving.kv_pages import (BlockTables, PageAllocator, PagedKVManager,
                                    PageStats)
from repro.serving.metrics import latency_summary, percentile
from repro.serving.sampler import greedy, sample

__all__ = ["AllocatorInvariantError", "BlockTables", "EngineStallError",
           "FaultInjector", "IterStats", "PageAllocator", "PagedKVManager",
           "PageStats", "PapiEngine", "ServeRequest", "ServeResult",
           "TokenEvent", "greedy", "latency_summary", "parse_fault_specs",
           "percentile", "sample"]
