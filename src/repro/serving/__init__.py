from repro.serving.engine import (AllocatorInvariantError, EngineStallError,
                                  IterStats, PapiEngine, ServeRequest,
                                  ServeResult, TokenEvent)
from repro.serving.faults import FaultInjector, parse_fault_specs
from repro.serving.kv_pages import (BlockTables, PageAllocator, PagedKVManager,
                                    PageStats)
from repro.serving.metrics import latency_summary, percentile
from repro.serving.sampler import greedy, sample
from repro.serving.telemetry import (NULL_TRACER, Event, NullTracer,
                                     ProgramTiming, Tracer, export_chrome,
                                     export_jsonl, export_prometheus,
                                     write_trace)

__all__ = ["AllocatorInvariantError", "BlockTables", "EngineStallError",
           "Event", "FaultInjector", "IterStats", "NULL_TRACER",
           "NullTracer", "PageAllocator", "PagedKVManager", "PageStats",
           "PapiEngine", "ProgramTiming", "ServeRequest", "ServeResult",
           "TokenEvent", "Tracer", "export_chrome", "export_jsonl",
           "export_prometheus", "greedy", "latency_summary",
           "parse_fault_specs", "percentile", "sample", "write_trace"]
