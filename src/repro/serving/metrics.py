"""Latency accounting for the continuous-batching serve loop.

The streaming front end (`PapiEngine.serve`) stamps every request with the
standard serving latencies:

  queue delay   submit -> first admission (how long the request sat behind
                the pool; PR 6's deferral/preemption machinery bounds it)
  TTFT          submit -> first streamed token (queue delay + prefill,
                the user-visible "time to first token")
  TPOT          mean gap between subsequent tokens ("time per output
                token"; (finish - first token) / (n_tokens - 1))

Each comes in two flavours: wall-clock seconds (what an operator cares
about, noisy on shared CI runners) and engine *iterations* (deterministic
for a fixed arrival schedule, so `tools/check_bench.py` can gate p99 TTFT
without flaking).  `latency_summary` aggregates a batch of `ServeResult`s
into p50/p99 per metric — the shape recorded in BENCH_engine.json's
``arrivals`` section.
"""
from __future__ import annotations

from typing import Iterable, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 for an empty input.

    Nearest-rank (not interpolated) so iteration-valued metrics stay
    integers and the BENCH gate compares exact values across runs.
    """
    if not values:
        return 0.0
    vals = sorted(values)
    if q <= 0:
        return vals[0]
    rank = max(1, -(-len(vals) * q // 100))  # ceil(len * q / 100)
    return vals[min(int(rank), len(vals)) - 1]


# ServeResult fields aggregated by latency_summary (each -> {p50, p99, mean})
METRIC_FIELDS = ("queue_delay_s", "ttft_s", "tpot_s",
                 "queue_delay_iters", "ttft_iters")


def latency_summary(results: Iterable) -> dict:
    """Aggregate per-request latencies into p50/p99/mean per metric.

    ``results`` is any iterable of objects with the `METRIC_FIELDS`
    attributes (normally `ServeResult`s from a serve() run).  Requests
    that never produced a token (cancelled/rejected before TTFT) carry
    ``ttft_s/tpot_s`` of None and are excluded from those metrics rather
    than dragging the percentiles to zero — likewise single-token requests
    from ``tpot_s`` (no inter-token gap exists; the engine stamps those
    None).  Each metric therefore carries its own ``count`` of
    contributing requests; the top-level ``n`` is the request total.
    """
    results = list(results)
    out: dict = {"n": len(results)}
    for field in METRIC_FIELDS:
        vals = [getattr(r, field) for r in results]
        vals = [v for v in vals if v is not None]
        out[field] = {
            "p50": percentile(vals, 50),
            "p99": percentile(vals, 99),
            "mean": (sum(vals) / len(vals)) if vals else 0.0,
            "count": len(vals),
        }
    return out
