"""Engine telemetry: typed event traces, per-program timing, exporters.

PAPI's whole mechanism is *online kernel characterization* — the runtime
watches per-kernel behavior and schedules compute-bound vs memory-bound
work accordingly (§5.2).  The engine therefore needs an observation layer
that is always available and (nearly) free when off:

  * `Tracer` — a bounded ring buffer of typed `Event`s: iteration spans,
    admission/chunk waves, scheduler decisions (estimate + threshold, not
    just the verdict), preemptions, deferrals, fault injections, degraded
    re-runs, page-pool occupancy samples, and per-request lifecycle marks
    (submit / admit / first_token / finish).  The buffer keeps the NEWEST
    events and counts what it dropped; aggregate counters and the
    per-program timing table live outside the ring, so exports stay exact
    under truncation.
  * per-compiled-program timing — `Tracer.timed_call(key, fn, *args)`
    wraps a jitted dispatch with wall time measured around
    `jax.block_until_ready`, keyed by the engine's jit-cache key
    ``(kind, tlp, fc_variant, interpret, attn_pim)``.  The running
    count/mean/min/max per key is exactly the table a
    measured-characterization scheduler consumes: it answers "what does
    the pu-vs-pim variant actually cost at this TLP" from data instead of
    a statically calibrated alpha.
  * `NullTracer` — the engine default.  Every hook is a no-op and
    `timed_call` is a bare dispatch (no block, no timing), so the
    traced-off hot path is unchanged (gated by the traced-vs-untraced A/B
    in ``benchmarks/engine_hotpath.py --arrivals --trace``).

Exporters (one event vocabulary, three views — see docs/ARCHITECTURE.md,
"Observability & telemetry"):

  * `export_chrome` — Chrome-trace-event JSON (`{"traceEvents": [...]}`),
    opens in Perfetto / chrome://tracing.  One lane per engine slot
    (request residency spans + first-token marks), one for the scheduler
    (iteration spans named by the chosen FC variant, flip instants), one
    for the page pool (a counter track), one for compiled-program
    dispatches, one for the queue (submit/defer/fault instants).  The
    full typed-event payload rides in each event's ``args`` and the
    aggregate tables under a top-level ``"papi"`` key, so
    `tools/trace_report.py` reads the same facts from either format.
  * `export_prometheus` — text-exposition snapshot of ``papi_engine_*``
    counters/gauges derived from the same events (iterations, tokens,
    finishes by reason, preemptions, deferrals, degraded steps, faults by
    kind, scheduler flips, pool occupancy, per-program run counts and
    total seconds).
  * `export_jsonl` — the raw typed events, one JSON object per line, with
    a trailing ``summary`` record carrying the aggregate tables.

Both the offline `PapiEngine.run()` and the streaming `serve()` loop emit
the same vocabulary, so one trace format covers every engine mode
(dense/paged x greedy/spec x mesh x faults).
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from collections import Counter, deque
from typing import Any, Iterable

# The event vocabulary.  `tools/trace_report.py` validates traces against
# this set, so additions here must be mirrored there (it keeps its own
# copy: the report tool is stdlib-only and must not import jax transitively).
EVENT_KINDS = frozenset({
    "submit",        # request entered the queue        {req_id, prompt_len, max_new}
    "admit",         # request first admitted to a slot {req_id, slot, prompt_len}
    "first_token",   # request's first output token     {req_id}
    "finish",        # result emitted                   {req_id, reason, tokens, slot}
    "preempt",       # in-flight request preempted      {req_id, slot, done}
    "defer",         # queue head deferred by the pool  {req_id, age}
    "scheduler",     # per-iteration decision           {ai_estimate, alpha,
                     #   assignment, flipped, rlp, tlp}
    "iteration",     # span: one engine step            {IterStats fields}
    "pool",          # page-pool occupancy sample       {used, free, watermark,
                     #   fragmentation}
    "fault",         # an injected fault fired          {fault, ...}
    "degraded",      # finite-logits guard re-ran the   {mode: step|wave}
                     #   step on the oracle path
    "program",       # span: one compiled-program       {key, ...}
                     #   dispatch (traced only)
    "page_map",      # allocator mapped pages           {slot, pages}
    "page_unmap",    # allocator returned pages         {slot, pages, cause}
    "page_reserve",  # admission reserved a budget      {slot, budget_pages,
                     #   mapped_pages}
    "stall",         # EngineStallError snapshot        {snapshot}
    "journal",       # WAL lifecycle                    {op, path, ...}
                     #   op="open" (torn tail truncated) / "snapshot"
    "recover",       # restore() re-admitted work       {path, resumed,
                     #   finished, records, torn_bytes, next_req_id}
})


@dataclasses.dataclass
class Event:
    """One typed trace event.  ``ts`` is seconds on the tracer's clock
    (zero at `Tracer` construction); ``dur`` is nonzero for spans."""
    kind: str
    iteration: int
    ts: float
    dur: float = 0.0
    data: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ProgramTiming:
    """Running timing stats for one compiled program (one jit-cache key)."""
    count: int = 0
    total_s: float = 0.0
    min_s: float = math.inf
    max_s: float = 0.0

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    def record(self, dur: float) -> None:
        self.count += 1
        self.total_s += dur
        self.min_s = min(self.min_s, dur)
        self.max_s = max(self.max_s, dur)

    def as_dict(self) -> dict:
        return {"count": self.count, "total_s": self.total_s,
                "mean_s": self.mean_s,
                "min_s": self.min_s if self.count else 0.0,
                "max_s": self.max_s}


def format_program_key(key: tuple) -> str:
    """Stable string form of a jit-cache key for export/labels, e.g.
    ``('spec_fused', 4, 'pim', None, False)`` -> ``spec_fused|4|pim|-|-``
    (None and False compress to '-': most keys are mostly defaults)."""
    return "|".join("-" if part in (None, False) else str(part)
                    for part in key)


class Tracer:
    """Bounded typed-event trace + per-program timing table.

    ``capacity`` bounds the event ring (the NEWEST events are kept;
    ``dropped`` counts the truncated prefix).  Aggregate ``counters``,
    ``gauges``, and the ``programs`` timing table are maintained at emit
    time, outside the ring, so the Prometheus snapshot and the report
    tool's tables stay exact regardless of truncation.

    ``page_events=True`` opts into the allocator's per-call
    map/unmap/reserve events even without ``debug_invariants`` (they are
    the highest-volume kind; the engine attaches the tracer to the page
    manager only when one of the two flags asks for them).
    """

    enabled = True

    def __init__(self, capacity: int = 65536, *, page_events: bool = False):
        assert capacity >= 1, capacity
        self.capacity = int(capacity)
        self.page_events = bool(page_events)
        self._events: deque[Event] = deque(maxlen=self.capacity)
        self.emitted = 0
        self.iteration = 0           # engine refreshes this every step
        self.counters: Counter = Counter()
        self.gauges: dict[str, float] = {}
        self.programs: dict[tuple, ProgramTiming] = {}
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------ emission
    @property
    def events(self) -> Iterable[Event]:
        return self._events

    @property
    def dropped(self) -> int:
        return self.emitted - len(self._events)

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def emit(self, kind: str, iteration: int | None = None, *,
             ts: float | None = None, dur: float = 0.0, **data) -> Event:
        """Append one typed event (newest-wins ring) and fold it into the
        aggregate counters/gauges."""
        ev = Event(kind,
                   self.iteration if iteration is None else int(iteration),
                   self._now() if ts is None else ts, dur, data)
        self._events.append(ev)
        self.emitted += 1
        self.counters[kind] += 1
        if kind == "finish":
            self.counters[f"finish:{data.get('reason', 'unknown')}"] += 1
        elif kind == "fault":
            self.counters[f"fault:{data.get('fault', 'unknown')}"] += 1
        elif kind == "scheduler" and data.get("flipped"):
            self.counters["scheduler_flip"] += 1
        elif kind == "iteration":
            self.counters["tokens"] += int(data.get("new_tokens", 0))
        elif kind == "pool":
            for field in ("used", "free", "watermark", "fragmentation"):
                if field in data:
                    self.gauges[f"kv_pages_{field}"] = data[field]
        return ev

    def span(self, kind: str, start: float, iteration: int | None = None,
             **data) -> Event:
        """Emit a span that began at absolute `time.perf_counter()` value
        ``start`` and ends now."""
        end = time.perf_counter()
        return self.emit(kind, iteration, ts=start - self._t0,
                         dur=end - start, **data)

    # ------------------------------------------------------ program timing
    def timed_call(self, key: tuple, fn, *args):
        """Dispatch ``fn(*args)`` and record its wall time (measured around
        `jax.block_until_ready`) against jit-cache key ``key``.  The block
        only happens under an enabled tracer — the engine's `_call` hook
        routes through the bare `fn(*args)` when tracing is off."""
        import jax   # deferred: exporters/report paths never need jax
        start = time.perf_counter()
        # papilint: allow-transfer(timed dispatch must block to measure device wall)
        out = jax.block_until_ready(fn(*args))
        self.record_program(key, time.perf_counter() - start, start=start)
        return out

    def record_program(self, key: tuple, dur: float,
                       start: float | None = None) -> None:
        self.programs.setdefault(key, ProgramTiming()).record(dur)
        ts = None if start is None else start - self._t0
        self.emit("program", ts=ts, dur=dur, key=format_program_key(key))

    def program_table(self) -> dict[str, dict]:
        """The per-key timing table, string-keyed for export: the exact
        shape a measured-characterization scheduler consumes."""
        return {format_program_key(k): t.as_dict()
                for k, t in sorted(self.programs.items(), key=lambda kv:
                                   format_program_key(kv[0]))}


class NullTracer:
    """The engine default: every hook is a no-op, ``timed_call`` is a bare
    dispatch.  Shares the read surface (events/counters/programs/...) so
    exporters degrade gracefully on an untraced engine."""

    enabled = False
    page_events = False
    iteration = 0
    emitted = 0
    dropped = 0
    events: tuple = ()
    counters: dict = {}
    gauges: dict = {}
    programs: dict = {}

    def emit(self, kind, iteration=None, *, ts=None, dur=0.0, **data):
        return None

    def span(self, kind, start, iteration=None, **data):
        return None

    def timed_call(self, key, fn, *args):
        return fn(*args)

    def record_program(self, key, dur, start=None):
        return None

    def program_table(self):
        return {}


NULL_TRACER = NullTracer()


# --------------------------------------------------------------- exporters
def _jsonable(obj):
    """json.dumps default= hook: numpy scalars -> python, else str."""
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return item()
        except (TypeError, ValueError):
            pass
    return str(obj)


# Chrome lane (tid) layout inside pid 1 ("papi-engine").  Slot lanes start
# at SLOT_TID0 so any max_slots fits after the fixed lanes.
SCHED_TID, POOL_TID, PROG_TID, QUEUE_TID, SLOT_TID0 = 1, 2, 3, 4, 10
_PID = 1


def export_chrome(tracer) -> dict:
    """Chrome-trace-event JSON (the ``traceEvents`` array format).

    Opens directly in Perfetto (ui.perfetto.dev) or chrome://tracing:
    iteration spans on the scheduler lane are named by the FC variant the
    scheduler chose (the pu<->pim flip pattern is visible at a glance,
    flips marked as instants), each slot lane shows request residency
    spans with first-token marks, the pool lane is a page-occupancy
    counter track, and the program lane shows every traced compiled-
    program dispatch.  The typed payload of every event rides in ``args``
    (with its ``kind``), and the aggregate counter/gauge/program tables
    under the top-level ``"papi"`` key — `tools/trace_report.py` consumes
    those rather than re-deriving from the lanes.
    """
    out: list[dict] = []

    def meta(tid: int, name: str) -> None:
        out.append({"ph": "M", "pid": _PID, "tid": tid, "ts": 0,
                    "name": "thread_name", "args": {"name": name}})

    out.append({"ph": "M", "pid": _PID, "tid": 0, "ts": 0,
                "name": "process_name", "args": {"name": "papi-engine"}})
    meta(SCHED_TID, "scheduler")
    meta(POOL_TID, "kv-page-pool")
    meta(PROG_TID, "programs")
    meta(QUEUE_TID, "queue")

    def us(ts: float) -> float:
        return ts * 1e6

    open_slots: dict[int, dict] = {}   # slot -> open residency span
    slot_lanes: set[int] = set()
    last_ts = 0.0

    def base(ev: Event, tid: int, ph: str, name: str) -> dict:
        return {"ph": ph, "pid": _PID, "tid": tid, "ts": us(ev.ts),
                "name": name,
                "args": {"kind": ev.kind, "iteration": ev.iteration,
                         **ev.data}}

    def close_slot(slot: int, ts: float, name_suffix: str = "") -> None:
        span = open_slots.pop(slot, None)
        if span is None:
            return
        span["dur"] = max(us(ts) - span["ts"], 0.0)
        span["name"] += name_suffix
        out.append(span)

    for ev in tracer.events:
        last_ts = max(last_ts, ev.ts + ev.dur)
        if ev.kind == "iteration":
            rec = base(ev, SCHED_TID, "X",
                       f"fc={ev.data.get('fc_variant', '?')}")
            rec["dur"] = us(ev.dur)
            out.append(rec)
        elif ev.kind == "scheduler":
            if ev.data.get("flipped"):
                rec = base(ev, SCHED_TID, "i",
                           f"flip->{ev.data.get('assignment')}")
                rec["s"] = "t"
                out.append(rec)
        elif ev.kind == "pool":
            rec = base(ev, POOL_TID, "C", "kv_pages")
            rec["args"] = {"used": ev.data.get("used", 0),
                           "free": ev.data.get("free", 0)}
            out.append(rec)
        elif ev.kind == "program":
            rec = base(ev, PROG_TID, "X", ev.data.get("key", "program"))
            rec["dur"] = us(ev.dur)
            out.append(rec)
        elif ev.kind == "admit":
            slot = ev.data.get("slot")
            if slot is not None:
                tid = SLOT_TID0 + int(slot)
                slot_lanes.add(int(slot))
                close_slot(int(slot), ev.ts)   # defensive: no dangling span
                open_slots[int(slot)] = base(
                    ev, tid, "X", f"req {ev.data.get('req_id')}")
        elif ev.kind in ("finish", "preempt"):
            slot = ev.data.get("slot")
            suffix = " (preempted)" if ev.kind == "preempt" else ""
            if slot is not None:
                close_slot(int(slot), ev.ts, suffix)
            rec = base(ev, QUEUE_TID, "i", f"{ev.kind} "
                       f"req {ev.data.get('req_id')}")
            rec["s"] = "t"
            out.append(rec)
        elif ev.kind == "first_token":
            rec = base(ev, QUEUE_TID, "i",
                       f"first_token req {ev.data.get('req_id')}")
            rec["s"] = "t"
            out.append(rec)
        elif ev.kind in ("submit", "defer", "fault", "degraded", "stall",
                         "page_map", "page_unmap", "page_reserve",
                         "journal", "recover"):
            rec = base(ev, QUEUE_TID, "i", ev.kind)
            rec["s"] = "t"
            out.append(rec)

    for slot in list(open_slots):
        close_slot(slot, last_ts, " (open)")
    for slot in sorted(slot_lanes):
        meta(SLOT_TID0 + slot, f"slot {slot}")

    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "papi": {
            "counters": dict(tracer.counters),
            "gauges": dict(tracer.gauges),
            "programs": tracer.program_table(),
            "events_emitted": tracer.emitted,
            "events_dropped": tracer.dropped,
        },
    }


def export_jsonl(tracer) -> str:
    """Raw typed events, one JSON object per line, newest-ring contents in
    order, with a trailing ``summary`` record carrying the aggregate
    tables (exact under ring truncation)."""
    lines = []
    for ev in tracer.events:
        lines.append(json.dumps(
            {"kind": ev.kind, "iteration": ev.iteration, "ts": ev.ts,
             "dur": ev.dur, "data": ev.data},
            default=_jsonable, sort_keys=True))
    lines.append(json.dumps(
        {"kind": "summary", "iteration": tracer.iteration,
         "ts": 0.0, "dur": 0.0,
         "data": {"counters": dict(tracer.counters),
                  "gauges": dict(tracer.gauges),
                  "programs": tracer.program_table(),
                  "events_emitted": tracer.emitted,
                  "events_dropped": tracer.dropped}},
        default=_jsonable, sort_keys=True))
    return "\n".join(lines) + "\n"


def _prom_escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"')


def export_prometheus(tracer) -> str:
    """Prometheus text-exposition snapshot of ``papi_engine_*`` series,
    derived from the tracer's aggregate counters/gauges (NOT the ring, so
    truncation never undercounts).  Counter series end in ``_total``;
    pool occupancy and per-program means are gauges."""
    c, g = tracer.counters, tracer.gauges
    lines: list[str] = []

    def metric(name: str, mtype: str, help_text: str,
               samples: list[tuple[str, float]]) -> None:
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {mtype}")
        for labels, value in samples:
            lines.append(f"{name}{labels} {value}")

    metric("papi_engine_iterations_total", "counter",
           "Engine iterations executed.", [("", c.get("iteration", 0))])
    # one labelled sample per EVENT_KINDS member, zero-filled, so the
    # exposition always covers the full event vocabulary (PL005's runtime
    # counterpart: a new kind shows up here without any exporter edit)
    metric("papi_engine_events_total", "counter",
           "Telemetry events recorded, by event kind.",
           [(f'{{kind="{_prom_escape(k)}"}}', c.get(k, 0))
            for k in sorted(EVENT_KINDS)])
    metric("papi_engine_tokens_total", "counter",
           "Output tokens committed.", [("", c.get("tokens", 0))])
    reasons = sorted(k.split(":", 1)[1] for k in c if k.startswith("finish:"))
    metric("papi_engine_requests_finished_total", "counter",
           "Requests finished, by finished_reason.",
           [(f'{{reason="{_prom_escape(r)}"}}', c[f"finish:{r}"])
            for r in reasons] or [("", 0)])
    metric("papi_engine_preemptions_total", "counter",
           "In-flight requests preempted under pool pressure.",
           [("", c.get("preempt", 0))])
    metric("papi_engine_deferrals_total", "counter",
           "Iterations the queue head was deferred by the pool.",
           [("", c.get("defer", 0))])
    metric("papi_engine_degraded_steps_total", "counter",
           "Iterations re-run on the oracle path by the finite-logits "
           "guard.", [("", c.get("degraded", 0))])
    kinds = sorted(k.split(":", 1)[1] for k in c if k.startswith("fault:"))
    metric("papi_engine_faults_injected_total", "counter",
           "Injected faults fired, by kind.",
           [(f'{{kind="{_prom_escape(k)}"}}', c[f"fault:{k}"])
            for k in kinds] or [("", 0)])
    metric("papi_engine_scheduler_flips_total", "counter",
           "Scheduler FC-path reschedules (pu<->pim).",
           [("", c.get("scheduler_flip", 0))])
    metric("papi_engine_kv_pages_used", "gauge",
           "KV pool pages holding live KV (latest sample).",
           [("", g.get("kv_pages_used", 0))])
    metric("papi_engine_kv_pages_free", "gauge",
           "KV pool pages on the free list (latest sample).",
           [("", g.get("kv_pages_free", 0))])
    metric("papi_engine_kv_page_watermark", "gauge",
           "Peak KV pool pages mapped over the engine lifetime.",
           [("", g.get("kv_pages_watermark", 0))])
    metric("papi_engine_kv_fragmentation", "gauge",
           "Tail-of-page waste share of mapped rows (latest sample).",
           [("", g.get("kv_pages_fragmentation", 0.0))])
    table = tracer.program_table()
    metric("papi_engine_program_runs_total", "counter",
           "Compiled-program dispatches, by jit-cache key.",
           [(f'{{key="{_prom_escape(k)}"}}', t["count"])
            for k, t in table.items()] or [("", 0)])
    metric("papi_engine_program_seconds_total", "counter",
           "Wall seconds inside compiled programs (around "
           "block_until_ready), by jit-cache key.",
           [(f'{{key="{_prom_escape(k)}"}}', t["total_s"])
            for k, t in table.items()] or [("", 0.0)])
    metric("papi_engine_program_mean_seconds", "gauge",
           "Mean wall seconds per dispatch, by jit-cache key.",
           [(f'{{key="{_prom_escape(k)}"}}', t["mean_s"])
            for k, t in table.items()] or [("", 0.0)])
    metric("papi_engine_trace_events_total", "counter",
           "Typed trace events emitted.", [("", tracer.emitted)])
    metric("papi_engine_trace_events_dropped_total", "counter",
           "Events truncated out of the ring buffer.",
           [("", tracer.dropped)])
    return "\n".join(lines) + "\n"


def write_trace(tracer, path, fmt: str = "chrome") -> None:
    """Serialize the trace to ``path``: ``chrome`` (Perfetto-openable JSON)
    or ``jsonl`` (raw typed events)."""
    from pathlib import Path
    p = Path(path)
    if fmt == "chrome":
        p.write_text(json.dumps(export_chrome(tracer), default=_jsonable)
                     + "\n")
    elif fmt == "jsonl":
        p.write_text(export_jsonl(tracer))
    else:
        raise ValueError(f"unknown trace format {fmt!r} "
                         "(choose 'chrome' or 'jsonl')")
