"""Write-ahead request journal + snapshot format for `PapiEngine`.

Durability rides the PR 6 invariant: a request resumed as
``prompt + tokens-so-far`` re-chunks through prefill **bit-identically**
to an uninterrupted run, so crash recovery never needs device state — it
re-admits every unfinished request through the `_ResumedRequest` path and
greedy/speculative decoding recomputes the lost tail exactly.  What must
survive the crash is therefore only host-side logical state: the queue,
each request's committed tokens, its remaining token budget, and its
remaining deadline (a monotonic-clock delta — wall timestamps would not
survive a restart).

Record grammar (append-only, one record per line)::

    J1 <len> <crc32:08x> <json>\\n

``<json>`` is a compact JSON object whose ``"k"`` key names the record
kind; ``<len>`` is the UTF-8 byte length of ``<json>`` and the checksum is
``zlib.crc32`` over those same bytes.  Kinds and their payloads:

  ``submit``   {req_id, prompt, max_new, dl}           caller submission
  ``resume``   {req_id, prompt, done, max_new, dl, plen}  restore() re-admission
               (prompt = ORIGINAL prompt; max_new / dl = REMAINING budgets)
  ``admit``    {req_id, slot, budget, it}   budget = admission-clamped
               remaining new-token budget (re-admission must clamp the
               same way preemption does, so the clamped value is logged)
  ``commit``   {req_id, toks, n, rem, dl, it}   tokens committed this
               step (delta), total after, remaining budgets
  ``preempt``  {req_id, done, it}            requeued at the back
  ``cancel``   {req_id, it}                  cooperative cancel accepted
  ``finish``   {req_id, reason, toks, n, it} result emitted; ``toks`` is
               the tail since the last commit, so the journal alone
               reconstructs every finished stream

Torn-tail rule: the reader walks the valid prefix and stops at the first
record that is truncated, checksum-corrupt, or unparseable — that record
and everything after it are discarded.  This is safe by construction:
commit records past the last consistent point are superseded by re-decode
(deterministic greedy/speculative acceptance recomputes the identical
tokens), and a lost ``finish`` record merely re-completes the request —
its recomputed stream still matches the oracle.  Exactly-once *delivery*
of finishes to a durable consumer holds when the consumer treats the
journal as the source of truth (a finish is "delivered" once its record
is durable); the ``fsync`` flush policy makes every record durable before
`PapiEngine` externalizes it.

`Journal` opened on an existing path validates the prefix and physically
truncates any torn tail, so a recovered engine can keep appending to the
SAME file — replay of the extended journal equals the uninterrupted
history, because re-decoded tokens land exactly where the discarded
records would have.

Flush policy (``Journal(path, flush=...)``):

  ``"fsync"``  flush + os.fsync after every record (exactly-once durable)
  ``"flush"``  flush after every record (default: survives process death,
               not power loss)
  ``"lazy"``   buffered; flushed on close() (fastest, at-least-once)
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from pathlib import Path
from typing import Any, Iterable

_MAGIC = b"J1"
FLUSH_POLICIES = ("fsync", "flush", "lazy")

# record kinds the writer accepts / the reader folds
RECORD_KINDS = ("submit", "resume", "admit", "commit", "preempt", "cancel",
                "finish")


def _frame(payload: dict) -> bytes:
    body = json.dumps(payload, separators=(",", ":")).encode("utf-8")
    return b"%s %d %08x %s\n" % (_MAGIC, len(body), zlib.crc32(body), body)


class Journal:
    """Append-only write-ahead journal (see the module docstring for the
    record grammar).  Opening an existing file validates it and truncates
    any torn tail, so appends always extend a consistent prefix."""

    def __init__(self, path: str | Path, *, flush: str = "flush") -> None:
        if flush not in FLUSH_POLICIES:
            raise ValueError(
                f"unknown flush policy {flush!r} (choose from "
                f"{FLUSH_POLICIES})")
        self.path = Path(path)
        self.flush = flush
        self.truncated_bytes = 0
        self.records_kept = 0
        if self.path.exists():
            records, valid_end, total = scan(self.path.read_bytes())
            self.records_kept = len(records)
            if valid_end < total:
                self.truncated_bytes = total - valid_end
                with open(self.path, "r+b") as fh:
                    fh.truncate(valid_end)
        self._fh = open(self.path, "ab")

    def append(self, kind: str, **fields: Any) -> None:
        assert kind in RECORD_KINDS, kind
        self._fh.write(_frame({"k": kind, **fields}))
        if self.flush != "lazy":
            self._fh.flush()
            if self.flush == "fsync":
                os.fsync(self._fh.fileno())

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def scan(data: bytes) -> tuple[list[dict], int, int]:
    """Walk the valid prefix of raw journal bytes.  Returns
    ``(records, valid_end, total)``: the decoded records, the byte offset
    where the valid prefix ends, and the total byte length.  The first
    truncated / corrupt / unparseable record stops the walk — it and
    everything after it are the torn tail."""
    records: list[dict] = []
    off = 0
    total = len(data)
    while off < total:
        nl = data.find(b"\n", off)
        if nl < 0:
            break                       # no newline: torn final record
        line = data[off:nl]
        parts = line.split(b" ", 3)
        if len(parts) != 4 or parts[0] != _MAGIC:
            break
        try:
            length, crc = int(parts[1]), int(parts[2], 16)
        except ValueError:
            break
        body = parts[3]
        if len(body) != length or zlib.crc32(body) != crc:
            break
        try:
            rec = json.loads(body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            break
        if not isinstance(rec, dict) or rec.get("k") not in RECORD_KINDS:
            break
        records.append(rec)
        off = nl + 1
    return records, off, total


def read_records(path: str | Path) -> tuple[list[dict], int]:
    """Decode the valid prefix of the journal at `path`.  Returns
    ``(records, torn_bytes)`` — torn_bytes counts the discarded tail."""
    records, valid_end, total = scan(Path(path).read_bytes())
    return records, total - valid_end


# --------------------------------------------------------------- recovery
@dataclasses.dataclass
class RecoveredRequest:
    """One unfinished request reconstructed from the journal / snapshot:
    exactly the payload `PapiEngine.restore` needs to rebuild a
    `_ResumedRequest` (original prompt, committed tokens, REMAINING token
    budget, REMAINING deadline delta)."""
    req_id: int
    prompt: list[int]            # ORIGINAL prompt (never the resumed one)
    done: list[int]              # tokens already committed
    max_new: int                 # remaining new-token budget
    deadline_s: float | None     # remaining deadline (monotonic delta)
    orig_prompt_len: int


@dataclasses.dataclass
class FinishedRequest:
    req_id: int
    reason: str
    tokens: list[int]            # the full committed stream
    # True when no finish record survived but the committed prefix already
    # exhausted the budget / hit eos: the finish was externalized before
    # the crash, so recovery must NOT re-run or re-emit it.
    synthesized: bool = False


@dataclasses.dataclass
class RecoveredState:
    """Folded logical state: the unfinished queue (in recovery order),
    the finished set, and the req-id counter."""
    requests: list[RecoveredRequest]
    finished: dict[int, FinishedRequest]
    next_req_id: int
    admit_seq: int = 0
    records: int = 0
    torn_bytes: int = 0

    @property
    def req_ids(self) -> list[int]:
        return [r.req_id for r in self.requests]


def replay(records: Iterable[dict], *, eos_token: int | None = None,
           torn_bytes: int = 0) -> RecoveredState:
    """Fold journal records into a `RecoveredState`.

    A pending request whose remaining budget hit zero — or whose last
    committed token is ``eos_token`` — lost only its finish record to the
    torn tail; it is synthesized into the finished set instead of being
    re-admitted, which is what makes finishes exactly-once."""
    records = list(records)
    pend: dict[int, dict] = {}
    finished: dict[int, FinishedRequest] = {}
    max_rid = -1
    for rec in records:
        rid = int(rec["req_id"])
        max_rid = max(max_rid, rid)
        kind = rec["k"]
        if kind == "submit":
            pend[rid] = dict(prompt=list(rec["prompt"]),
                             plen=len(rec["prompt"]), done=[],
                             rem=int(rec["max_new"]), dl=rec.get("dl"))
        elif kind == "resume":
            pend.pop(rid, None)
            pend[rid] = dict(prompt=list(rec["prompt"]),
                             plen=int(rec["plen"]), done=list(rec["done"]),
                             rem=int(rec["max_new"]), dl=rec.get("dl"))
        elif kind == "admit":
            if rid in pend:
                pend[rid]["rem"] = int(rec["budget"])
        elif kind == "commit":
            e = pend.get(rid)
            if e is not None:
                e["done"] += list(rec["toks"])
                e["rem"] = int(rec["rem"])
                if rec.get("dl") is not None:
                    e["dl"] = rec["dl"]
        elif kind == "preempt":
            if rid in pend:      # requeued at the back: recovery keeps that
                pend[rid] = pend.pop(rid)
        elif kind == "finish":
            e = pend.pop(rid, {"done": []})
            finished[rid] = FinishedRequest(
                rid, rec["reason"], list(e["done"]) + list(rec["toks"]))
        # "cancel" is informational: the engine emits the authoritative
        # finish record (reason="cancelled") through the same path as any
        # other completion
    requests: list[RecoveredRequest] = []
    for rid, e in pend.items():
        hit_eos = (eos_token is not None and e["done"]
                   and e["done"][-1] == eos_token)
        if e["rem"] <= 0 or hit_eos:
            finished[rid] = FinishedRequest(
                rid, "eos" if hit_eos else "length", list(e["done"]),
                synthesized=True)
            continue
        requests.append(RecoveredRequest(
            req_id=rid, prompt=list(e["prompt"]), done=list(e["done"]),
            max_new=int(e["rem"]), deadline_s=e["dl"],
            orig_prompt_len=int(e["plen"])))
    return RecoveredState(requests=requests, finished=finished,
                          next_req_id=max_rid + 1, records=len(records),
                          torn_bytes=torn_bytes)


# --------------------------------------------------------------- snapshot
SNAPSHOT_VERSION = 1


def write_snapshot(path: str | Path, state: dict) -> None:
    """Atomically write an engine snapshot dict: tmp + fsync + rename +
    directory fsync, so neither a process crash nor a power loss
    mid-snapshot leaves a half-written file where restore expects a
    consistent one (without the data fsync the rename can survive a power
    loss while the bytes do not)."""
    path = Path(path)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "w") as fh:
        fh.write(json.dumps(state, indent=2) + "\n")
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    try:
        dfd = os.open(path.parent, os.O_RDONLY)
    except OSError:               # platform can't open directories
        return
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def _snapshot_state(snap: dict) -> RecoveredState:
    requests = [RecoveredRequest(
        req_id=int(r["req_id"]), prompt=list(r["prompt"]),
        done=list(r["done"]), max_new=int(r["max_new"]),
        deadline_s=r.get("deadline_s"),
        orig_prompt_len=int(r["orig_prompt_len"]))
        for r in snap["requests"]]
    finished = {int(f["req_id"]): FinishedRequest(
        int(f["req_id"]), f["reason"], list(f.get("tokens", [])))
        for f in snap.get("finished", [])}
    return RecoveredState(requests=requests, finished=finished,
                          next_req_id=int(snap.get("next_req_id", 0)),
                          admit_seq=int(snap.get("admit_seq", 0)))


def recover(path: str | Path, *, eos_token: int | None = None
            ) -> RecoveredState:
    """Load a snapshot file OR a journal file into a `RecoveredState`.
    Snapshots are JSON dicts carrying ``"papi_snapshot"``; anything else
    is read as a framed journal (torn tail discarded)."""
    data = Path(path).read_bytes()
    if data.lstrip()[:1] == b"{":
        snap = json.loads(data.decode("utf-8"))
        if snap.get("papi_snapshot") != SNAPSHOT_VERSION:
            raise ValueError(
                f"{path}: unsupported snapshot version "
                f"{snap.get('papi_snapshot')!r}")
        state = _snapshot_state(snap)
        # the eos/budget guard applies to snapshots too (a snapshot taken
        # right at a finish boundary must not re-run the request)
        keep = []
        for r in state.requests:
            hit_eos = (eos_token is not None and r.done
                       and r.done[-1] == eos_token)
            if r.max_new <= 0 or hit_eos:
                state.finished[r.req_id] = FinishedRequest(
                    r.req_id, "eos" if hit_eos else "length", list(r.done),
                    synthesized=True)
            else:
                keep.append(r)
        state.requests = keep
        return state
    records, valid_end, total = scan(data)
    return replay(records, eos_token=eos_token, torn_bytes=total - valid_end)
