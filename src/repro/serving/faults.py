"""Deterministic fault injection for the serving engine.

The engine's failure model (see `docs/ARCHITECTURE.md`, "Failure model &
graceful degradation") is only trustworthy if every failure path can be
*forced* in a test, reproducibly.  `FaultInjector` is that forcing
function: a seeded, per-iteration schedule of faults the engine consults
at well-defined points of `PapiEngine.step()`.

Fault taxonomy (what each kind models, and which guard catches it):

  ``admit``     Allocator admission failure — the pool reports "busy" even
                when pages are free (a stand-in for external memory
                pressure or an allocator bug).  Caught by the deferral
                path: the head of the queue defers, `IterStats`
                deferral age grows, and pool-pressure preemption /
                the no-progress watchdog bound the wait.
  ``nan``       NaN logits out of the decode step (numerically-poisoned
                weights, a bad rescale).  Caught by the jitted
                finite-logits guard: the step is discarded and re-run on
                the XLA oracle path with the speculation window clamped
                to 1 (``IterStats.degraded``).
  ``kernel``    Kernel-output corruption modeled as an overflowed
                accumulator: logits forced to +inf.  Caught by the same
                finite-logits guard (isfinite rejects inf and NaN alike).
  ``latency``   Artificial per-step host latency (a slow collective, a
                straggler shard).  Nothing to "catch" — it exists so the
                deadline machinery (`ServeRequest.deadline_s`) can be
                exercised against a deterministically slow engine.
  ``crash``     Process death at the top of the iteration: the engine
                raises `EngineCrashError` with NO cleanup — results,
                pages, and journal tail are simply lost.  Caught by the
                durability layer (`serving/journal.py`): a fresh engine
                `restore()`s from the write-ahead journal and the
                recovered streams are bit-identical continuations.

Determinism: every decision is a pure function of ``(seed, iteration)``
(`numpy.random.default_rng([seed, step])`), so a run replays exactly
regardless of how many times a step consults the injector, and two
engines with the same seed see the same fault schedule.

The logits faults are applied *inside* the jitted fused step: the engine
passes the per-iteration fault code as a traced int32 scalar
(`FAULT_NONE/FAULT_NAN/FAULT_INF`), so injection costs no retrace and the
oracle re-run (which takes the unfused path, no fault argument) is clean
by construction.  Under ``fused=False`` the engine is already running the
oracle path end to end, so logits faults are not applied there.

CLI: `launch.serve --fault kind[:prob]` builds an injector via
`parse_fault_specs` (repeatable, e.g. ``--fault nan:0.2 --fault admit:0.5``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# fault codes the jitted steps consume (traced int32 scalar)
FAULT_NONE = 0
FAULT_NAN = 1
FAULT_INF = 2

KINDS = ("admit", "nan", "kernel", "latency", "crash")


@dataclasses.dataclass
class FaultInjector:
    """Seeded per-iteration fault schedule.

    Each ``*_p`` is the per-iteration probability of that fault firing;
    ``window`` optionally restricts injection to iterations
    ``start <= it < stop`` (None = unbounded).  ``counts`` records what
    actually fired, keyed by kind.
    """

    seed: int = 0
    admit_p: float = 0.0
    nan_p: float = 0.0
    kernel_p: float = 0.0
    latency_p: float = 0.0
    latency_s: float = 0.002
    crash_p: float = 0.0
    start: int = 0
    stop: int | None = None

    def __post_init__(self) -> None:
        self.counts: dict[str, int] = {k: 0 for k in KINDS}

    # ------------------------------------------------------------- schedule
    def _draws(self, step: int) -> np.ndarray:
        """Five uniforms, a pure function of (seed, step): one per kind, so
        the kinds fire independently and a repeated consult replays.  The
        crash draw was APPENDED — `Generator.random(n)` consumes the
        bitstream sequentially, so the first four uniforms (and therefore
        every pre-existing fault schedule) are unchanged."""
        return np.random.default_rng([self.seed, int(step)]).random(5)

    def _active(self, step: int) -> bool:
        return step >= self.start and (self.stop is None or step < self.stop)

    # ------------------------------------------------------ engine consults
    def admission_blocked(self, step: int) -> bool:
        """Force this iteration's admission to report the pool busy."""
        hit = self._active(step) and self._draws(step)[0] < self.admit_p
        if hit:
            self.counts["admit"] += 1
        return hit

    def logits_fault(self, step: int) -> int:
        """FAULT_NAN / FAULT_INF / FAULT_NONE for this iteration's decode.
        NaN wins when both fire — one corrupted value per step is enough."""
        if not self._active(step):
            return FAULT_NONE
        draws = self._draws(step)
        if draws[1] < self.nan_p:
            self.counts["nan"] += 1
            return FAULT_NAN
        if draws[2] < self.kernel_p:
            self.counts["kernel"] += 1
            return FAULT_INF
        return FAULT_NONE

    def step_delay(self, step: int) -> float:
        """Artificial host latency (seconds) to sleep before the decode."""
        hit = self._active(step) and self._draws(step)[3] < self.latency_p
        if hit:
            self.counts["latency"] += 1
            return self.latency_s
        return 0.0

    def crash_now(self, step: int) -> bool:
        """Kill the engine at the top of this iteration (the engine raises
        `EngineCrashError` and performs NO cleanup — the whole point)."""
        hit = self._active(step) and self._draws(step)[4] < self.crash_p
        if hit:
            self.counts["crash"] += 1
        return hit


def parse_fault_specs(specs: list[str], *, seed: int = 0,
                      latency_s: float = 0.002) -> FaultInjector | None:
    """Build an injector from CLI specs like ``["nan:0.2", "admit"]``.

    Each spec is ``kind[:prob]`` (prob defaults to 1.0).  Returns None for
    an empty list so callers can pass the result straight to
    ``PapiEngine(faults=...)``.
    """
    if not specs:
        return None
    probs = {k: 0.0 for k in KINDS}
    for spec in specs:
        kind, _, prob = spec.partition(":")
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} (choose from {KINDS})")
        try:
            p = float(prob) if prob else 1.0
        except ValueError:
            raise ValueError(
                f"fault spec {spec!r}: probability {prob!r} is not a number"
            ) from None
        if not 0.0 <= p <= 1.0:
            raise ValueError(
                f"fault spec {spec!r}: probability {p} outside [0, 1]")
        probs[kind] = p
    return FaultInjector(seed=seed, admit_p=probs["admit"],
                         nan_p=probs["nan"], kernel_p=probs["kernel"],
                         latency_p=probs["latency"], latency_s=latency_s,
                         crash_p=probs["crash"])
