"""Token sampling + speculative acceptance for the serving engine.

`accept_speculative` is the device-side half of PAPI's lossless greedy
speculation: it runs *inside* the engine's fused decode step so the
accept-longest-prefix decision never leaves the accelerator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits [..., V] -> token ids [...]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
           top_k: int = 0) -> jax.Array:
    """Temperature + top-k sampling.  ``temperature <= 0`` is greedy;
    ``top_k <= 0`` disables the top-k filter, and ``top_k >= vocab`` is a
    no-op filter (every token survives) rather than an out-of-range index
    into the sorted logits."""
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        k = min(int(top_k), logits.shape[-1])
        kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def accept_speculative(
    window: jax.Array,      # [b, k] int32: draft window, window[:, 0] is the
                            #   last committed token, window[:, 1:] proposals
    target: jax.Array,      # [b, k] int32: target-model greedy outputs
) -> tuple[jax.Array, jax.Array]:
    """Vectorized accept-longest-prefix (lossless greedy speculation).

    For each row, `accepted = 1 + n` where n is the length of the longest
    prefix with ``window[:, i+1] == target[:, i]`` — the target's correction
    token after the matched prefix is always accepted ("free token"), so
    accepted is in [1, k].  Returns ``(out, accepted)`` with `out[b, j]` =
    `target[b, j]` for `j < accepted[b]` and 0 beyond (masked padding).

    Equivalent to the per-slot Python reference:

        n = 0
        while n < k - 1 and window[s, n + 1] == target[s, n]:
            n += 1
        accepted[s] = n + 1
        out[s, :n + 1] = target[s, :n + 1]
    """
    b, k = window.shape
    if k == 1:
        return target.astype(jnp.int32), jnp.ones((b,), jnp.int32)
    match = (window[:, 1:] == target[:, :-1]).astype(jnp.int32)   # [b, k-1]
    prefix = jnp.cumprod(match, axis=1)                           # [b, k-1]
    accepted = 1 + jnp.sum(prefix, axis=1)                        # [b] 1..k
    mask = jnp.arange(k)[None, :] < accepted[:, None]
    out = jnp.where(mask, target, 0)
    return out.astype(jnp.int32), accepted.astype(jnp.int32)
