"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits [..., V] -> token ids [...]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
           top_k: int = 0) -> jax.Array:
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)
