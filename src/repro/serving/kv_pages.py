"""Paged KV-cache bookkeeping: Attn-PIM bank-row allocator + block tables.

PAPI's Attn-PIM units hold the KV cache in fixed-size DRAM banks (§5.2/§5.3);
the natural allocation quantum is one bank *row* — what this module calls a
page.  Instead of pre-reserving a dense `(slots, capacity, ...)` slab per
request (worst-case provisioning: every request pays for the longest), the
engine maps each request's KV onto physical pages through a block table:

  logical token position  t  of slot  s
      -> logical block    t // page_size
      -> physical page    block_tables[s, t // page_size]
      -> bank row offset  t %  page_size

Three pieces live here, all host-side (the device only ever sees the
`[max_slots, max_blocks]` int32 block-table array):

  * `PageAllocator` — a LIFO free list with **admission reservations**: a
    request is admitted only if its whole worst-case page budget
    (prompt + max_new_tokens + speculative window) is available, but pages
    are *mapped* lazily as the sequence grows.  Reserved-but-unmapped pages
    are subtracted from the headroom every admission checks, so a grow()
    can never fail mid-flight and a speculative rewind can safely return
    pages to the free list (the reservation keeps them claimable).
  * `BlockTables` — the host mirror of the device block tables.  Unmapped
    entries point at the shared GARBAGE_PAGE (see below) and the device
    array is re-materialized only when a row actually changed.
  * `PagedKVManager` — the engine-facing facade tying both together and
    translating token counts to page counts.

The garbage page
----------------
Physical page 0 is permanently reserved and never allocated.  Idle slots in
the fixed-shape decode batch still execute (their outputs are masked on the
host — the standard padded-batch trade), and their KV writes must land
*somewhere* that no live request owns.  Every unmapped block-table entry
points at page 0, so garbage writes collide harmlessly there; live requests
never reference it (entries past a request's mapped prefix are either
clamped away by the paged kernel's index_map or masked by `cache_len`).

Invariants (property-tested in `tests/test_kv_pages.py`):
  * a physical page is never mapped to two owners at once;
  * free + mapped partitions the usable pool exactly;
  * reserved-unmapped never exceeds the free count (grow() cannot fail);
  * after all owners finish, the pool is back to all-free.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

GARBAGE_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages covering `tokens` KV entries (>= 1 page per owner so
    a mapped row always exists for the first write)."""
    return max(1, -(-int(tokens) // page_size))


@dataclasses.dataclass(frozen=True)
class PageStats:
    """Pool-level snapshot surfaced per iteration via `IterStats`."""
    num_pages: int            # usable pool size (garbage page excluded)
    page_size: int
    free: int                 # pages on the free list right now
    mapped: int               # pages currently holding live KV
    reserved_unmapped: int    # admission-reserved, not yet mapped
    watermark: int            # peak mapped page count over the pool lifetime
    fragmentation: float      # 1 - used_tokens / (mapped * page_size)


class PageAllocator:
    """Free-list page allocator with admission reservations.

    Pages are plain ints in `[first_page, first_page + num_pages)`.  The
    free list is LIFO — recently-freed (cache-warm) pages are reused first.

    The reservation model: `admit(owner, budget, initial)` maps `initial`
    pages now and records `budget - initial` as reserved-unmapped.  The
    admission headroom is `free - total_reserved_unmapped`, so once a
    request is in, its `grow()` calls draw from its own reservation and are
    guaranteed to succeed; `rewind()` puts mapped pages back on the free
    list but *keeps* the reservation, so speculative rollback can never
    strand a request (the pages it returns stay claimable by it alone).
    """

    def __init__(self, num_pages: int, page_size: int, *, first_page: int = 0):
        assert num_pages >= 1 and page_size >= 1, (num_pages, page_size)
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.first_page = int(first_page)
        # LIFO: low page ids come off the stack first (reversed range)
        self._free: list[int] = list(
            range(first_page + num_pages - 1, first_page - 1, -1))
        self._mapped: dict[int, list[int]] = {}
        self._reserved: dict[int, int] = {}
        self.watermark = 0

    # ----------------------------------------------------------- accounting
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def mapped_count(self) -> int:
        return sum(len(p) for p in self._mapped.values())

    @property
    def reserved_unmapped(self) -> int:
        return sum(self._reserved.values())

    @property
    def available(self) -> int:
        """Pages an admission may still claim (free minus already-promised)."""
        return len(self._free) - self.reserved_unmapped

    def owners(self) -> list[int]:
        return list(self._mapped)

    def pages_of(self, owner: int) -> list[int]:
        return list(self._mapped.get(owner, ()))

    # ------------------------------------------------------------ lifecycle
    def can_admit(self, budget_pages: int) -> bool:
        return 0 < budget_pages <= self.available

    def admit(self, owner: int, budget_pages: int,
              initial_pages: int) -> list[int]:
        """Reserve `budget_pages` for `owner`, mapping `initial_pages` now."""
        assert owner not in self._mapped and owner not in self._reserved, owner
        assert 1 <= initial_pages <= budget_pages, (initial_pages, budget_pages)
        if not self.can_admit(budget_pages):
            raise MemoryError(
                f"admit({owner}): {budget_pages} pages > {self.available} "
                "available")
        pages = [self._free.pop() for _ in range(initial_pages)]
        self._mapped[owner] = pages
        self._reserved[owner] = budget_pages - initial_pages
        self.watermark = max(self.watermark, self.mapped_count)
        return list(pages)

    def grow(self, owner: int, n_pages: int) -> list[int]:
        """Map `n_pages` more for `owner`.  Draws from the owner's
        reservation first (guaranteed present), then — e.g. when the engine
        widens the speculative window mid-flight — from the uncommitted
        headroom; only the latter can fail."""
        if n_pages <= 0:
            return []
        assert owner in self._mapped, owner
        over = n_pages - self._reserved[owner]
        if over > 0 and over > self.available:
            raise MemoryError(
                f"grow({owner}, {n_pages}): {over} pages beyond the "
                f"reservation, {self.available} uncommitted available")
        pages = [self._free.pop() for _ in range(n_pages)]
        self._mapped[owner].extend(pages)
        self._reserved[owner] = max(0, self._reserved[owner] - n_pages)
        self.watermark = max(self.watermark, self.mapped_count)
        return list(pages)

    def reserve_more(self, owner: int, n_pages: int) -> None:
        """Adjust `owner`'s unmapped reservation by `n_pages` (the engine
        re-budgets live requests when the speculative window changes
        mid-flight).  Widening draws on the uncommitted headroom and fails
        if it isn't there; shrinking clamps at zero — an owner whose mapped
        pages already exceed the new budget simply has nothing reserved."""
        assert owner in self._mapped, owner
        if n_pages > 0:
            if n_pages > self.available:
                raise MemoryError(
                    f"reserve_more({owner}, {n_pages}): only "
                    f"{self.available} uncommitted pages available")
            self._reserved[owner] += n_pages
        else:
            self._reserved[owner] = max(0, self._reserved[owner] + n_pages)

    def rewind(self, owner: int, keep_pages: int) -> list[int]:
        """Return mapped pages beyond the first `keep_pages` to the free
        list, **keeping the reservation** (speculative rollback: the pages
        stay claimable by this owner).  Returns the freed page ids so the
        caller can scrub its block-table row."""
        assert owner in self._mapped, owner
        row = self._mapped[owner]
        keep_pages = max(1, keep_pages)       # never unmap the first page
        if keep_pages >= len(row):
            return []
        freed = row[keep_pages:]
        del row[keep_pages:]
        self._reserved[owner] += len(freed)
        self._free.extend(reversed(freed))    # LIFO: rewound pages reused next
        return list(freed)

    def finish(self, owner: int) -> list[int]:
        """Release everything `owner` holds — mapped pages and reservation."""
        pages = self._mapped.pop(owner, [])
        self._reserved.pop(owner, None)
        self._free.extend(reversed(pages))
        return list(pages)

    # -------------------------------------------------------------- queries
    def fragmentation(self, used_tokens: int) -> float:
        """Internal fragmentation: share of mapped bank rows holding no live
        token (tail-of-page waste).  0.0 when nothing is mapped."""
        cap = self.mapped_count * self.page_size
        if cap == 0:
            return 0.0
        return 1.0 - min(int(used_tokens), cap) / cap

    def stats(self, used_tokens: int = 0) -> PageStats:
        return PageStats(
            num_pages=self.num_pages,
            page_size=self.page_size,
            free=self.free_count,
            mapped=self.mapped_count,
            reserved_unmapped=self.reserved_unmapped,
            watermark=self.watermark,
            fragmentation=self.fragmentation(used_tokens),
        )

    def snapshot(self) -> dict:
        """Plain-dict state dump for diagnostics: the structured engine
        errors (`EngineStallError`, `AllocatorInvariantError`) attach this
        so a post-mortem can see exactly who held what."""
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "free": self.free_count,
            "mapped": {owner: list(row)
                       for owner, row in self._mapped.items()},
            "reserved": dict(self._reserved),
            "available": self.available,
            "watermark": self.watermark,
        }

    def check(self) -> None:
        """Assert the pool invariants (used by the property tests)."""
        mapped = [p for row in self._mapped.values() for p in row]
        assert len(mapped) == len(set(mapped)), "page double-mapped"
        assert not (set(mapped) & set(self._free)), "mapped page on free list"
        assert len(mapped) + len(self._free) == self.num_pages, (
            "pages leaked", len(mapped), len(self._free), self.num_pages)
        assert self.reserved_unmapped <= len(self._free), (
            "reservation exceeds free pool — grow() could fail")
        lo, hi = self.first_page, self.first_page + self.num_pages
        assert all(lo <= p < hi for p in mapped + self._free)


class BlockTables:
    """Host mirror of the device block tables: `[max_slots, max_blocks]`
    int32 physical page ids.  Unmapped entries hold GARBAGE_PAGE.  The
    device array is rebuilt lazily, only after a mutation."""

    def __init__(self, max_slots: int, max_blocks: int):
        self.max_slots, self.max_blocks = int(max_slots), int(max_blocks)
        self.host = np.full((max_slots, max_blocks), GARBAGE_PAGE, np.int32)
        self._device = None

    def set_row(self, slot: int, pages: Iterable[int]) -> None:
        pages = list(pages)
        assert len(pages) <= self.max_blocks, (len(pages), self.max_blocks)
        self.host[slot, :len(pages)] = pages
        self.host[slot, len(pages):] = GARBAGE_PAGE
        self._device = None

    def clear_row(self, slot: int) -> None:
        self.host[slot, :] = GARBAGE_PAGE
        self._device = None

    def device(self):
        """The jnp array the jitted steps consume (cached until dirty)."""
        if self._device is None:
            import jax.numpy as jnp
            self._device = jnp.asarray(self.host)
        return self._device


class PagedKVManager:
    """Engine-facing facade: token-count API over the allocator + tables.

    One manager serves both the target and (when speculating) the draft
    cache: the draft's KV lives at the same logical positions, so both
    caches index their own page pools through the SAME block tables —
    `page_size` and `num_pages` are shared geometry, the page *contents*
    (k/v arrays) are per-model.
    """

    def __init__(self, *, num_pages: int, page_size: int, max_slots: int,
                 max_blocks: int | None = None):
        usable = int(num_pages) - 1          # page 0 = garbage page
        assert usable >= 1, f"num_pages={num_pages} leaves no usable page"
        if max_blocks is None:
            max_blocks = usable
        # optional telemetry sink: the engine attaches its Tracer here
        # (under debug_invariants or an opted-in tracer — per-call page
        # events are the trace's highest-volume kind) and every map/unmap/
        # reserve below emits a typed event.  None = zero-cost.
        self.tracer = None
        self.page_size = int(page_size)
        # a table wider than the pool would let admission accept a budget
        # the allocator can never satisfy even when fully drained — the
        # request would defer forever (livelock, since deferral blocks the
        # queue waiting for pages that do not exist)
        self.max_blocks = min(int(max_blocks), usable)
        self.alloc = PageAllocator(usable, page_size, first_page=1)
        self.tables = BlockTables(max_slots, self.max_blocks)

    @property
    def max_context(self) -> int:
        """Longest sequence one request can hold (table width bound)."""
        return self.max_blocks * self.page_size

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_admit(self, budget_tokens: int) -> bool:
        need = self.pages_for(budget_tokens)
        return need <= self.max_blocks and self.alloc.can_admit(need)

    def admit(self, slot: int, budget_tokens: int,
              initial_tokens: int) -> None:
        budget = self.pages_for(budget_tokens)
        pages = self.alloc.admit(slot, budget,
                                 self.pages_for(initial_tokens))
        self.tables.set_row(slot, pages)
        if self.tracer is not None:
            self.tracer.emit("page_reserve", slot=slot, budget_pages=budget,
                             mapped_pages=len(pages))

    def coverage(self, slot: int) -> int:
        """Tokens the slot's mapped pages can hold right now.  Under the
        continuous-batching serve loop this is the live-pressure frontier:
        admission maps only chunk 0's pages and each later wave `ensure()`s
        its own chunk, so coverage trails the reserved budget until the
        prompt finishes prefilling (the pool watermark follows demand, not
        the worst case)."""
        return len(self.alloc.pages_of(slot)) * self.page_size

    def ensure(self, slot: int, tokens: int) -> int:
        """Grow slot coverage to `tokens`; returns pages newly mapped."""
        have = len(self.alloc.pages_of(slot))
        need = self.pages_for(tokens)
        if need <= have:
            return 0
        self.alloc.grow(slot, need - have)
        self.tables.set_row(slot, self.alloc.pages_of(slot))
        if self.tracer is not None:
            self.tracer.emit("page_map", slot=slot, pages=need - have)
        return need - have

    def rewind(self, slot: int, tokens: int) -> int:
        """Return pages past `tokens` coverage to the pool (speculative
        rollback); returns pages freed."""
        freed = self.alloc.rewind(slot, self.pages_for(tokens))
        if freed:
            self.tables.set_row(slot, self.alloc.pages_of(slot))
            if self.tracer is not None:
                self.tracer.emit("page_unmap", slot=slot, pages=len(freed),
                                 cause="rewind")
        return len(freed)

    def release(self, slot: int) -> int:
        """Drop everything `slot` holds — mapped pages AND the unmapped
        reservation — and scrub its block-table row back to the garbage
        page.  This is the preemption/cancel/timeout drain as much as the
        normal finish: a preempted request re-enters admission later as a
        fresh `admit()` with a fresh reservation, and the scrubbed row
        guarantees its old pages can be re-issued to any other slot without
        aliasing."""
        freed = self.alloc.finish(slot)
        self.tables.clear_row(slot)
        if self.tracer is not None and freed:
            self.tracer.emit("page_unmap", slot=slot, pages=len(freed),
                             cause="release")
        return len(freed)

    def stats(self, used_tokens: int = 0) -> PageStats:
        return self.alloc.stats(used_tokens)
