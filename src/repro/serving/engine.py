"""PAPI serving engine: mixed continuous batching + speculative decoding +
dynamic FC-path scheduling.

The runtime loop the paper describes (§5.2.2), realized over the JAX models:

  1. admit waiting requests into free KV-cache slots (mixed continuous
     batching — token-level scheduling, no drain barrier);
  2. run one decoding iteration for every active slot: either a plain
     decode step (TLP=1) or a draft-propose / target-verify speculative
     window (TLP>1, greedy & lossless);
  3. gather the iteration's output tokens, count <|eos|>, update the
     scheduler's RLP; the scheduler compares RLP*TLP against the calibrated
     alpha and picks the FC execution path ("pu" MXU vs "pim" fc_gemv) for
     the *next* iteration.

Slots are fixed-capacity (static shapes: the decode step is compiled once
per TLP value).  Inactive slots decode garbage that is masked out — the
standard padded-batch serving trade.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import PapiScheduler
from repro.models import decode_step, init_cache, prefill
from repro.models.linear import fc_variant
from repro.serving.sampler import greedy


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    prompt: list[int]
    max_new_tokens: int


@dataclasses.dataclass
class ServeResult:
    req_id: int
    tokens: list[int]
    prompt_len: int
    iterations: int
    finished_reason: str = "length"


@dataclasses.dataclass
class IterStats:
    iteration: int
    rlp: int
    tlp: int
    ai_estimate: float
    fc_variant: str
    new_tokens: int
    accepted: float        # mean accepted tokens per active slot (spec dec)
    wall_s: float


class PapiEngine:
    """Single-host serving engine (the multi-pod deployment lowers the same
    step functions through `launch.serve`)."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_slots: int = 8,
        cache_capacity: int = 256,
        prefill_len: int = 64,
        alpha: float = 32.0,
        spec_len: int = 1,
        draft: tuple[ModelConfig, Any] | None = None,
        eos_token: int = 2,
        pim_interpret: bool | None = None,
    ) -> None:
        assert cfg.has_decode_step, f"{cfg.name} is encoder-only"
        self.cfg, self.params = cfg, params
        self.max_slots = max_slots
        self.capacity = cache_capacity
        self.prefill_len = prefill_len
        self.eos_token = eos_token
        self.spec_len = spec_len
        self.pim_interpret = pim_interpret
        self.scheduler = PapiScheduler(cfg, alpha=alpha, tlp=spec_len,
                                       eos_token=eos_token)
        self.scheduler.initial_schedule(0, spec_len)

        self.cache = init_cache(cfg, max_slots, cache_capacity)
        # per-slot host state
        self.slot_req: list[ServeRequest | None] = [None] * max_slots
        self.slot_tokens: list[list[int]] = [[] for _ in range(max_slots)]
        self.slot_last: np.ndarray = np.zeros(max_slots, np.int32)
        self.queue: list[ServeRequest] = []
        self.results: list[ServeResult] = []
        self.stats: list[IterStats] = []
        self.iteration = 0

        if draft is not None:
            self.draft_cfg, self.draft_params = draft
            self.draft_cache = init_cache(self.draft_cfg, max_slots,
                                          cache_capacity)
        else:
            self.draft_cfg = self.draft_params = self.draft_cache = None

        self._decode_jit: dict[tuple[str, int], Any] = {}
        self._prefill_jit: dict[str, Any] = {}

    # ------------------------------------------------------------------ API
    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    @property
    def active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def run(self, max_iterations: int = 10_000) -> list[ServeResult]:
        while (self.queue or self.active_slots) and self.iteration < max_iterations:
            self.step()
        return self.results

    # ------------------------------------------------------------- internals
    def _get_decode(self, which: str):
        tlp = 1 if which == "draft" else (self.spec_len if which == "verify" else 1)
        key = (which, tlp)
        if key not in self._decode_jit:
            cfg = self.draft_cfg if which == "draft" else self.cfg
            fn = partial(decode_step, cfg)
            self._decode_jit[key] = jax.jit(fn)
        return self._decode_jit[key]

    def _admit(self) -> int:
        """Mixed continuous batching: fill free slots from the queue."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        admitted = 0
        while self.queue and free:
            slot = free.pop(0)
            req = self.queue.pop(0)
            # never let a request outgrow its slot's KV capacity
            budget = self.capacity - min(len(req.prompt), self.prefill_len)
            req.max_new_tokens = min(req.max_new_tokens,
                                     budget - max(self.spec_len, 1))
            self._prefill_slot(slot, req)
            if self.draft_cfg is not None:
                self._prefill_slot(slot, req, draft=True)
            # prefill already produced the first output token
            first = int(self.slot_last[slot])
            self.slot_tokens[slot] = [first]
            if first == self.eos_token or req.max_new_tokens <= 1:
                reason = "eos" if first == self.eos_token else "length"
                self.results.append(ServeResult(
                    req.req_id, [first], len(req.prompt), self.iteration,
                    reason,
                ))
                free.insert(0, slot)     # slot stays available
            else:
                self.slot_req[slot] = req
                admitted += 1            # counts toward RLP
        return admitted

    def _prefill_slot(self, slot: int, req: ServeRequest,
                      draft: bool = False) -> None:
        cfg = self.draft_cfg if draft else self.cfg
        params = self.draft_params if draft else self.params
        cache = self.draft_cache if draft else self.cache
        p = min(len(req.prompt), self.prefill_len)
        toks = np.zeros((1, self.prefill_len), np.int32)
        toks[0, :p] = req.prompt[-self.prefill_len:][:p]
        batch = {
            "tokens": jnp.asarray(toks),
            "prompt_lens": jnp.asarray([p], jnp.int32),
        }
        tmp_cache = init_cache(cfg, 1, self.capacity)
        key = "draft" if draft else "main"
        if key not in self._prefill_jit:
            self._prefill_jit[key] = jax.jit(partial(prefill, cfg))
        logits, tmp_cache = self._prefill_jit[key](params, batch, tmp_cache)
        # scatter the single-request cache into the slot
        for k in ("k", "v"):
            if k in cache:
                cache[k] = cache[k].at[:, slot].set(tmp_cache[k][:, 0])
        if "ssm" in cache:
            cache["ssm"] = jax.tree.map(
                lambda d, s: d.at[:, slot].set(s[:, 0]), cache["ssm"],
                tmp_cache["ssm"],
            )
        cache["pos"] = cache["pos"].at[slot].set(p)
        if not draft:
            self.slot_last[slot] = int(np.argmax(np.asarray(logits[0])))

    def _decode_all(self) -> tuple[np.ndarray, np.ndarray]:
        """One decoding iteration for all slots.  Returns (new token matrix
        [slots, <=tlp], accepted counts [slots])."""
        variant = self.scheduler.fc_assignment
        tlp = self.spec_len
        with fc_variant(variant, interpret=self.pim_interpret):
            if tlp <= 1 or self.draft_cfg is None:
                toks = jnp.asarray(self.slot_last[:, None])
                logits, self.cache = self._get_decode("plain")(
                    self.params, self.cache, toks
                )
                nxt = np.asarray(greedy(logits[:, -1]))
                return nxt[:, None], np.ones(self.max_slots)
            return self._speculative_iteration()

    def _speculative_iteration(self) -> tuple[np.ndarray, np.ndarray]:
        """Greedy draft-propose / target-verify (lossless)."""
        k = self.spec_len
        draft_fn = self._get_decode("draft")
        # 1) draft proposes k-1 tokens autoregressively.  It runs k steps —
        # the extra step writes KV for the window's final token, so the
        # draft cache covers every token the target might accept (keeps the
        # two caches in lockstep when the full window is accepted).
        proposals = [self.slot_last.copy()]
        last = jnp.asarray(self.slot_last[:, None])
        for _ in range(k):
            logits, self.draft_cache = draft_fn(
                self.draft_params, self.draft_cache, last
            )
            nxt = greedy(logits[:, -1])
            proposals.append(np.asarray(nxt))
            last = nxt[:, None]
        window = np.stack(proposals[:k], axis=1)          # [slots, k]

        # 2) target verifies the window in ONE decode step (TLP = k)
        logits, self.cache = self._get_decode("verify")(
            self.params, self.cache, jnp.asarray(window)
        )
        target = np.asarray(greedy(logits))               # [slots, k]

        # 3) accept longest matching prefix; roll back caches per slot
        accepted = np.zeros(self.max_slots, np.int64)
        out = np.zeros((self.max_slots, k), np.int32)
        for s in range(self.max_slots):
            n = 0
            while n < k - 1 and window[s, n + 1] == target[s, n]:
                n += 1
            accepted[s] = n + 1                            # +1: free token
            out[s, : n + 1] = target[s, : n + 1]
        # target cache advanced by k for every slot; rewind to accepted
        rewind = jnp.asarray(k - accepted, jnp.int32)
        self.cache["pos"] = self.cache["pos"] - rewind
        # resync draft cache to the target position
        if self.draft_cache is not None:
            self.draft_cache["pos"] = jnp.minimum(
                self.draft_cache["pos"], self.cache["pos"]
            )
        return out, accepted.astype(np.float64)

    def step(self) -> None:
        t0 = time.perf_counter()
        admitted = self._admit()
        active = self.active_slots
        if not active:
            self.scheduler.observe_counts(0, admitted)
            return

        out, accepted = self._decode_all()

        # host-side bookkeeping: append tokens, detect eos / length
        iter_tokens: list[int] = []
        finished = 0
        for s in active:
            req = self.slot_req[s]
            assert req is not None
            n_acc = int(accepted[s]) if accepted is not None else 1
            for j in range(n_acc):
                tok = int(out[s, j])
                self.slot_tokens[s].append(tok)
                iter_tokens.append(tok)
                if tok == self.eos_token or (
                    len(self.slot_tokens[s]) >= req.max_new_tokens
                ):
                    reason = "eos" if tok == self.eos_token else "length"
                    self.results.append(ServeResult(
                        req.req_id, self.slot_tokens[s], len(req.prompt),
                        self.iteration, reason,
                    ))
                    self.slot_req[s] = None
                    finished += 1
                    break
            else:
                self.slot_last[s] = self.slot_tokens[s][-1]
                continue
            # slot freed: park its position on a safe nonzero value
            self.slot_last[s] = 0

        # park inactive slots at pos=1 so their garbage decode can't creep
        # past the cache capacity (they are masked from outputs anyway)
        inactive = [i for i in range(self.max_slots) if self.slot_req[i] is None]
        if inactive:
            idx = jnp.asarray(inactive)
            self.cache["pos"] = self.cache["pos"].at[idx].set(1)
            if self.draft_cache is not None:
                self.draft_cache["pos"] = self.draft_cache["pos"].at[idx].set(1)

        # 4) the PAPI runtime scheduling step (§5.2.2)
        self.scheduler.observe_counts(finished, admitted)
        self.iteration += 1
        self.stats.append(IterStats(
            iteration=self.iteration,
            rlp=self.scheduler.rlp,
            tlp=self.scheduler.tlp,
            ai_estimate=self.scheduler.ai_estimate,
            fc_variant=self.scheduler.fc_assignment,
            new_tokens=len(iter_tokens),
            accepted=float(np.mean(accepted[active])) if len(active) else 0.0,
            wall_s=time.perf_counter() - t0,
        ))

    def set_spec_len(self, tlp: int) -> None:
        """Host updates the TLP register (dynamic speculation length)."""
        self.spec_len = tlp
        self.scheduler.set_tlp(tlp)
