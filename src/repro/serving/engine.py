"""PAPI serving engine: mixed continuous batching + speculative decoding +
dynamic FC-path scheduling.

The runtime loop the paper describes (§5.2.2), realized over the JAX models:

  1. admit waiting requests into free KV-cache slots (mixed continuous
     batching — token-level scheduling, no drain barrier);
  2. run one decoding iteration for every active slot: either a plain
     decode step (TLP=1) or a draft-propose / target-verify speculative
     window (TLP>1, greedy & lossless);
  3. gather the iteration's output tokens, count <|eos|>, update the
     scheduler's RLP; the scheduler compares RLP*TLP against the calibrated
     alpha and picks the FC execution path ("pu" MXU vs "pim" fc_gemv) for
     the *next* iteration.

Slots are fixed-capacity (static shapes: the decode step is compiled once
per TLP value).  Inactive slots decode garbage that is masked out — the
standard padded-batch serving trade.

Chunked prefill (no prompt truncation, ever)
--------------------------------------------
``prefill_len`` sizes the compiled prefill *window*, not the longest
servable prompt.  Admission feeds an arbitrarily long prompt through the
fixed-shape program in waves of `prefill_len`-token chunks: chunk 0 runs
the batched `prefill_to_slots` / `prefill_to_pages` call (positions
0..P-1), every later chunk runs `models.prefill_chunk` — the decode path
at the slot's running offset, with per-slot masked KV writes so the ragged
final chunk and concurrently-decoding slots never touch each other's
cache.  Only the final chunk's logits produce the request's first output
token, which makes the stream bit-identical to a one-shot prefill of the
whole prompt (tested against that oracle).  Dense admission budgets the
slab for ``len(prompt) + max_new + spec window`` and rejects honestly
(``finished_reason="rejected"``) when the FULL prompt cannot fit; paged
admission reserves pages for the full prompt up front and maps them before
chunk 0, so every chunk scatters straight onto its pages.  A prompt that
fits one window takes exactly the pre-chunking path.

KV layouts (``kv_layout=``)
---------------------------
``"dense"`` (default): one `(layers, max_slots, cache_capacity, ...)` slab;
every request pre-reserves a full uniform slot, so per-request context is
capped at `cache_capacity` and short requests strand the rest of theirs.

``"paged"``: the Attn-PIM bank-row layout.  KV lives in a pool of
fixed-size pages (`models.init_paged_cache`), per-slot block tables map
logical KV blocks to physical pages, and `serving.kv_pages.PagedKVManager`
runs admission on a PAGE budget: a request enters iff pages for
`prompt + max_new_tokens + spec_len` are available (reserved up front,
mapped lazily as the sequence grows, returned on speculative rewind, freed
on finish).  A single request may span nearly the whole pool — context
length is bounded by pooled memory, not a per-slot slab.  Decode attention
either gathers pages into the XLA path or — with ``attn_pim=True`` — runs
the block-table Pallas kernel (`kernels.paged_decode_attention`), which
resolves pages inside its index_map for ANY TLP (plain decode, speculative
verify windows, chunked-prefill waves): `gather_kv_pages` never appears in
a jitted program under attn_pim.  Token streams are identical to the
dense engine on any workload both can hold (tested).  Per-iteration pool
stats (pages used/free, watermark, fragmentation) ride on `IterStats`.

Failure model & graceful degradation
------------------------------------
The engine degrades instead of livelocking or emitting garbage (see
docs/ARCHITECTURE.md for the full policy):

  * **pool-pressure preemption** — when paged admission has deferred the
    head of the queue for ``preempt_after`` consecutive iterations (or the
    pool occupancy crosses ``preempt_watermark`` while a deferral is
    pending), the YOUNGEST in-flight request is preempted: its pages are
    released and it is requeued at the back as ``prompt + tokens-so-far``,
    which chunked prefill recomputes bit-identically (the requeued
    request's first output token is exactly the decode step the preemption
    skipped).  The oldest in-flight request is never preempted, so it
    always runs to completion and the head of the queue always admits in
    bounded time — no livelock.
  * **deadlines and cancellation** — ``ServeRequest.deadline_s`` bounds a
    request's wall-clock time from submit(); `cancel(req_id)` works on
    queued and in-flight requests alike.  Both finish honestly
    (``finished_reason="timeout"/"cancelled"``) with tokens-so-far and
    drain their pages/reservations.
  * **finite-logits guard** — every fused decode step checks its logits
    for NaN/Inf ON DEVICE; a poisoned step is discarded (the functional
    cache update is simply not assigned) and the iteration re-runs on the
    tested XLA oracle path — unfused plain decode, "pu" FC, XLA attention
    — with the speculation window clamped to 1 for that step
    (`IterStats.degraded`).  `serving.faults.FaultInjector` forces this
    path (and admission failure / artificial latency) deterministically.
  * **no-progress watchdog** — ``stall_limit`` consecutive iterations in
    which nothing was admitted, decoded, finished, or preempted while work
    is pending raise `EngineStallError` carrying a pool/queue/slot
    snapshot, instead of spinning silently to ``max_iterations``.  `run()`
    exhaustion itself no longer drops in-flight requests: they are
    returned as ``finished_reason="aborted"`` results with tokens-so-far,
    pages released.
  * **invariant checking** — ``debug_invariants=True`` runs the page
    allocator's `check()` every iteration and turns a violation into
    `AllocatorInvariantError` with the allocator snapshot attached (the
    whole serving test suite runs with the flag on).

Device-resident hot path
------------------------
PAPI's premise is that the per-iteration scheduling decision is O(1) and a
reschedule costs nothing but the dispatch — which only holds if the Python
orchestration around the decode step is free.  The default (``fused=True``)
hot path therefore keeps one engine iteration (nearly) a single device
program:

  * the k-step draft loop + target verify + accept-longest-prefix +
    cache-rewind run inside ONE jitted function (`jax.lax.scan` over the
    draft steps, vectorized accept via `sampler.accept_speculative`), and
    the host fetches one `(out, accepted, finished_eos)` bundle per
    iteration instead of k+1 per-step syncs;
  * admission prefils ALL newly-freed slots in one compiled
    `models.prefill_to_slots` call (fixed [max_slots, prefill_len] batch +
    a [max_slots] src map), replacing the per-request temp-cache
    allocation + per-key `.at[slot].set` scatter;
  * inactive slots are parked with a fixed-shape boolean mask
    (`jnp.where(mask, 1, pos)`) instead of the recompile-prone dynamic
    `jnp.asarray(inactive)` gather index.

``fused=False`` preserves the seed's per-draft-step host loop and per-slot
Python accept reference — kept as the oracle for the property tests and the
`benchmarks/engine_hotpath.py` A/B.

Mesh execution (§5.3)
---------------------
Pass ``mesh=`` (e.g. `launch.mesh.make_serving_mesh(dp, tp)`) and the engine
becomes mesh-native; ``rules`` defaults to
`distributed.sharding.serve_rules()`:

  * params are `device_put` onto `models.param_shardings` — FC weights split
    over the tensor ("model") axis, i.e. one FC-PIM weight bank per shard;
  * the KV cache is placed by `models.cache_shardings` — under serve rules
    the cache *sequence* dim lands on the tensor axis (context-parallel KV
    slices); with ``attn_pim=True`` the rules instead store the cache split
    over KV *heads*, the same units the flash-decode kernel shard_maps over,
    so each Attn-PIM shard sits next to its resident KV slice and no
    per-step resharding occurs;
  * every jitted entry point (prefill waves, both fused step programs, the
    legacy host loop) is traced inside ``axis_rules(rules, mesh)``, so the
    `shard()` annotations in the model resolve and GSPMD partitions the
    step.  The "pim" FC path additionally runs `fc_gemv` under `shard_map`
    (see `models.linear`), and ``attn_pim=True`` routes every decode-path
    attention — plain decode, TLP>1 speculative verify windows, and
    chunked-prefill waves — through the (windowed) flash-decode Pallas
    kernel sharded one unit per KV-head shard.

The scheduler's per-iteration FC_PU <-> FC_PIM flip keeps working under a
mesh because the jit caches are keyed on the variant — each (kind, tlp,
variant) traces its own partitioned executable once, and a reschedule is
still just the dispatch of the other one.  Greedy token streams are
unchanged by the mesh (reduction reorder moves logits by ulps, never the
argmax — asserted 1-device vs 8-device in `tests/test_serving_sharded.py`).

Compiled-function cache keys
----------------------------
All jitted entry points are cached on ``(kind, tlp, fc_variant,
pim_interpret)``.  The FC variant MUST be part of the key: `papi_linear`
reads the variant from a host thread-local at *trace* time, so a cache
keyed only on (kind, tlp) — as the seed did — would bake in whichever
variant was active at first call and silently ignore every later scheduler
flip.  With the variant in the key, each path traces at most twice (pu +
pim) and a reschedule really is just a dispatch of the other executable.

Host-transfer accounting: every device->host sync goes through
`PapiEngine._fetch`, which bumps ``host_transfers``; per-iteration deltas
are recorded in `IterStats.transfers` so the benchmark can count round
trips instead of guessing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import PapiScheduler
from repro.debug.sanitize import EngineSanitizer
from repro.distributed.sharding import axis_rules, serve_rules
from repro.models import (cache_shardings, decode_step, init_cache,
                          init_paged_cache, mixed_step,
                          paged_cache_shardings, param_shardings,
                          prefill_chunk, prefill_to_pages, prefill_to_slots)
from repro.models.layers import attn_impl
from repro.models.linear import current_fc_interpret, current_fc_variant, fc_variant
from repro.serving.faults import FAULT_INF, FAULT_NAN, FAULT_NONE, FaultInjector
from repro.serving.journal import (SNAPSHOT_VERSION, Journal, recover,
                                   write_snapshot)
from repro.serving.kv_pages import PagedKVManager
from repro.serving.sampler import accept_speculative, greedy
from repro.serving.telemetry import NULL_TRACER, Tracer

# the serving subsystem's logger: deferral (DEBUG), preemption / unhappy
# finishes (INFO), degraded re-runs (WARNING), stalls (ERROR).  Unconfigured
# it propagates to the root handler-less logger, i.e. stays silent —
# `launch.serve --log-level` wires basicConfig for the CLI.
log = logging.getLogger("repro.serving")


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    prompt: list[int]
    max_new_tokens: int
    # wall-clock budget in seconds, measured from submit(); None = unbounded.
    # An expired request finishes with finished_reason="timeout" and its
    # tokens-so-far at the next step boundary.
    deadline_s: float | None = None


@dataclasses.dataclass
class ServeResult:
    req_id: int
    tokens: list[int]
    prompt_len: int
    iterations: int
    finished_reason: str = "length"
    # --- per-request serving latencies (see serving/metrics.py) ---
    # wall-clock seconds; None when the phase never happened (a request
    # cancelled in the queue has no TTFT).  The *_iters twins count engine
    # iterations instead — deterministic for a fixed arrival schedule, so
    # the BENCH gate can bound p99 TTFT without wall-clock flake.
    queue_delay_s: float | None = None   # submit -> first admission
    ttft_s: float | None = None          # submit -> first token
    tpot_s: float | None = None          # mean inter-token gap after TTFT
    queue_delay_iters: int | None = None
    ttft_iters: int | None = None


@dataclasses.dataclass
class TokenEvent:
    """One streamed event from `PapiEngine.serve`: a committed token on a
    live request, or (``finished=True``) the request's completion.  The
    final event carries ``token == -1``, ``index == len(result.tokens)``,
    the ``finished_reason`` and the full `ServeResult`; per-token events
    index the caller-visible stream (a preempted request's indices continue
    across its re-admission — the re-prefilled tokens are never re-sent)."""
    req_id: int
    token: int
    index: int
    iteration: int
    finished: bool = False
    reason: str | None = None
    result: ServeResult | None = None


@dataclasses.dataclass
class _ResumedRequest:
    """Internal requeue record for a preempted request: the original prompt
    extended with every token already emitted, so chunked admission
    recomputes the KV bit-identically and the continuation's first output
    token is exactly the decode step the preemption skipped.  The caller's
    `ServeRequest` is never touched; `done` / `orig_prompt_len` let result
    emission reassemble the caller-visible stream."""
    req_id: int
    prompt: list[int]          # original prompt + tokens emitted so far
    max_new_tokens: int        # remaining generation budget
    deadline_s: float | None
    done: list[int]            # tokens emitted before the preemption(s)
    orig_prompt_len: int


class EngineStallError(RuntimeError):
    """`run()` made no progress — nothing admitted, decoded, finished, or
    preempted — for `stall_limit` consecutive iterations while requests
    were still pending.  ``snapshot`` carries the pool/queue/slot state at
    the stall (see `PapiEngine._snapshot`)."""

    def __init__(self, message: str, snapshot: dict):
        super().__init__(message)
        self.snapshot = snapshot


class EngineCrashError(RuntimeError):
    """A `crash` fault fired: the engine dies at the top of the iteration,
    exactly like a process kill — no results emitted, no pages drained,
    no journal finalization.  Recovery cold-starts a fresh engine and
    `restore()`s from the journal/snapshot (see serving/journal.py)."""

    def __init__(self, message: str, iteration: int):
        super().__init__(message)
        self.iteration = iteration


class AllocatorInvariantError(RuntimeError):
    """A `debug_invariants=True` engine caught the page allocator violating
    its invariants (double-map / leak / over-reservation).  ``snapshot``
    carries the engine + allocator state at the violation."""

    def __init__(self, message: str, snapshot: dict):
        super().__init__(message)
        self.snapshot = snapshot


def _inject_fault(logits, code):
    """Apply the iteration's fault code (a traced int32 scalar) to the
    logits inside the jitted step: FAULT_NAN poisons with NaN, FAULT_INF
    models an overflowed kernel accumulator.  FAULT_NONE is the identity,
    so fault-free engines trace the same program."""
    poison = jnp.where(code == FAULT_NAN, jnp.nan, jnp.inf)
    return jnp.where(code == FAULT_NONE, logits,
                     jnp.full_like(logits, poison))


@dataclasses.dataclass
class IterStats:
    iteration: int
    rlp: int
    tlp: int
    ai_estimate: float
    fc_variant: str
    new_tokens: int
    accepted: float        # mean accepted tokens per active slot (spec dec)
    wall_s: float
    transfers: int = 0     # device->host sync round-trips this iteration
    # failure-model counters (see the module docstring):
    preemptions: int = 0   # in-flight requests preempted this iteration
    deferral_age: int = 0  # consecutive iterations the queue head deferred
    degraded: int = 0      # 1 if the finite-logits guard degraded this step
    # paged KV layout only (zeros under the dense layout):
    kv_pages_used: int = 0       # pages holding live KV right now
    kv_pages_free: int = 0       # pages on the free list
    kv_page_watermark: int = 0   # peak pages used over the engine lifetime
    kv_fragmentation: float = 0.0  # tail-of-page waste share of mapped rows
    # continuous-batching serve loop only (zeros under offline run()):
    arrivals: int = 0        # requests that arrived this iteration
    admitted: int = 0        # requests admitted to slots this iteration
    queued: int = 0          # queue depth after this iteration's admission
    prefill_slots: int = 0   # slots mid-chunked-prefill this iteration
    decode_slots: int = 0    # slots that ran a decode step this iteration


class PapiEngine:
    """Serving engine over one device by default, or over a whole mesh.

    ``mesh``/``rules`` make the engine mesh-native (see the module
    docstring): params and the KV cache are placed on `serve_rules()`
    shardings and every compiled step runs partitioned.  ``attn_pim=True``
    additionally moves every decode-path attention — plain decode,
    speculative verify windows (TLP>1), chunked-prefill waves, dense or
    paged — onto the (windowed) Pallas flash-decode kernel, the Attn-PIM
    unit, sharded per KV shard under a mesh.  `launch.serve` drives both
    layouts from the CLI."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_slots: int = 8,
        cache_capacity: int = 256,
        prefill_len: int = 64,
        alpha: float = 32.0,
        spec_len: int = 1,
        draft: tuple[ModelConfig, Any] | None = None,
        eos_token: int = 2,
        pim_interpret: bool | None = None,
        fused: bool = True,
        mesh: Any | None = None,
        rules: dict | None = None,
        attn_pim: bool = False,
        kv_layout: str = "dense",
        page_size: int = 16,
        num_pages: int | None = None,
        max_blocks: int | None = None,
        faults: FaultInjector | None = None,
        preempt_after: int | None = 8,
        preempt_watermark: float | None = None,
        stall_limit: int | None = 256,
        debug_invariants: bool = False,
        tracer: Tracer | None = None,
        sanitize: bool = False,
        journal: Journal | str | None = None,
    ) -> None:
        assert cfg.has_decode_step, f"{cfg.name} is encoder-only"
        assert kv_layout in ("dense", "paged"), kv_layout
        self.cfg, self.params = cfg, params
        self.max_slots = max_slots
        self.capacity = cache_capacity
        self.prefill_len = prefill_len
        self.eos_token = eos_token
        self.spec_len = spec_len
        self.pim_interpret = pim_interpret
        self.fused = fused
        self.mesh = mesh
        self.kv_layout = kv_layout
        # attn_pim stores the KV cache head-sharded instead of seq-sharded so
        # the flash-decode kernel's per-KV-shard units match the resident
        # layout (no per-step resharding) — see serve_rules(attn_pim=True).
        # The paged layout always takes the head-sharded rules under a mesh:
        # its pool dim replaced the sequence dim and physical page ids index
        # the whole pool, so KV heads are the only dim that can divide the
        # pools across devices (seq-sharded rules would silently replicate
        # the entire pool on every device).
        self.rules = (dict(rules) if rules is not None
                      else (serve_rules(attn_pim=attn_pim
                                        or kv_layout == "paged")
                            if mesh is not None else None))
        self.attn_pim = attn_pim
        # telemetry: NULL_TRACER's hooks are no-ops and its timed_call is a
        # bare dispatch, so the traced-off hot path is unchanged (gated by
        # the traced-vs-untraced A/B in benchmarks/engine_hotpath.py)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # runtime sanitizer (repro.debug.sanitize): transfer-guard scopes
        # around every step, per-iteration transfer-budget assertions, and
        # a jit-cache compile census — None keeps the hot path untouched
        self._sanitizer = EngineSanitizer() if sanitize else None
        self.scheduler = PapiScheduler(cfg, alpha=alpha, tlp=spec_len,
                                       eos_token=eos_token)
        self.scheduler.initial_schedule(0, spec_len)

        self.kv: PagedKVManager | None = None
        if kv_layout == "paged":
            # default pool: the same KV bytes the dense layout would hold
            # (max_slots dense slots of cache_capacity), plus the garbage
            # page — but pooled, so ONE request may span nearly all of it
            if num_pages is None:
                num_pages = max(max_slots * cache_capacity // page_size, 1) + 1
            # max_blocks bounds the block-table width: per-request context
            # AND the width of the XLA path's gathered KV view (which pays
            # for max_blocks * page_size per slot per step regardless of
            # live length).  Default None = the whole usable pool, i.e. one
            # request may span nearly all of it; cap it when serving many
            # short requests from a large pool.
            self.kv = PagedKVManager(num_pages=num_pages, page_size=page_size,
                                     max_slots=max_slots,
                                     max_blocks=max_blocks)
            # per-call allocator events (map/unmap/reserve) are the highest-
            # volume kind: attached only when debugging invariants or when
            # the tracer opted in explicitly
            if self.tracer.enabled and (debug_invariants
                                        or self.tracer.page_events):
                self.kv.tracer = self.tracer
            self.cache = init_paged_cache(cfg, max_slots, num_pages,
                                          page_size, self.kv.max_blocks)
        else:
            self.cache = init_cache(cfg, max_slots, cache_capacity)
        if mesh is not None:
            self.params = jax.device_put(
                self.params, param_shardings(cfg, self.rules, mesh))
            self.cache = jax.device_put(
                self.cache, self._cache_shardings(cfg))
        # per-slot host state
        self.slot_req: list[ServeRequest | None] = [None] * max_slots
        self.slot_tokens: list[list[int]] = [[] for _ in range(max_slots)]
        self.slot_last: np.ndarray = np.zeros(max_slots, np.int32)
        # full prompt tokens prefilled per slot (chunked admission writes the
        # whole prompt): the device cache position of a live slot is
        # slot_prompt[s] + len(slot_tokens[s]) - 1 (see _slot_pos)
        self.slot_prompt: np.ndarray = np.zeros(max_slots, np.int32)
        # effective generation budget per slot — the admission-clamped
        # max_new_tokens lives HERE, never written back into the caller's
        # ServeRequest (resubmitting the same object must see it pristine)
        self.slot_budget: np.ndarray = np.zeros(max_slots, np.int64)
        self.queue: list[ServeRequest] = []
        self.results: list[ServeResult] = []
        self.stats: list[IterStats] = []
        self.iteration = 0
        self.host_transfers = 0
        # --- failure model (see the module docstring) ---
        self.faults = faults
        self.preempt_after = preempt_after
        self.preempt_watermark = preempt_watermark
        self.stall_limit = stall_limit
        self.debug_invariants = debug_invariants
        # admission order per slot: the victim policy preempts the highest
        # sequence number (youngest), never the lowest (oldest)
        self._admit_seq = 0
        self.slot_seq: list[int] = [0] * max_slots
        self._defer_head: int | None = None   # req_id of the deferring head
        self._defer_age = 0                   # consecutive deferred steps
        self._deferred_head: int | None = None  # set by _admit on deferral
        self._degraded_this_step = False
        self._stalled = 0                     # consecutive no-progress steps
        self.preemptions = 0                  # engine-lifetime total
        self.degraded_steps = 0               # engine-lifetime total
        self.preempted_ids: set[int] = set()
        # wall-clock submit time (deadline base) and admission-delay
        # bookkeeping, keyed by req_id; first submission/admission wins
        self._submit_t: dict[int, float] = {}
        self.submit_iteration: dict[int, int] = {}
        self.admit_iteration: dict[int, int] = {}
        # latency accounting for serve(): wall-clock admission / first-token
        # stamps (setdefault — a preempted request keeps its originals)
        self._admit_t: dict[int, float] = {}
        self._first_tok_t: dict[int, float] = {}
        self.first_token_iteration: dict[int, int] = {}
        # --- durability (serving/journal.py) ---
        # write-ahead journal: a path opens (and torn-tail-truncates) a
        # Journal with the default flush policy; pass a Journal instance to
        # choose the policy.  _journal_done tracks tokens already journaled
        # per req_id so the end-of-step flush appends only deltas.
        if journal is None or isinstance(journal, Journal):
            self.journal: Journal | None = journal
        else:
            self.journal = Journal(journal)
        self._journal_done: dict[int, int] = {}
        if self.journal is not None and self.tracer.enabled:
            self.tracer.emit("journal", 0, op="open",
                             path=str(self.journal.path),
                             records=self.journal.records_kept,
                             truncated_bytes=self.journal.truncated_bytes)
        # --- continuous batching (serve()) ---
        # prompt tokens prefilled so far per slot; a slot is MID-PREFILL
        # while slot_offset < slot_prompt (only possible under serve(),
        # which spreads chunk waves across iterations — offline admission
        # always runs a prompt's waves to completion inside _admit_wave)
        self.slot_offset: np.ndarray = np.zeros(max_slots, np.int64)
        self.stream_chunks = False   # serve() flips this on for its lifetime
        self._arrived_this_step = 0  # set by serve(), recorded in IterStats
        # chunked prefill masks its KV writes per slot; SSM state has no
        # sequence dim to mask, so stateful families keep single-window
        # prefill and reject longer prompts honestly
        self._can_chunk = cfg.family in ("dense", "moe", "vlm", "audio")

        if draft is not None:
            self.draft_cfg, self.draft_params = draft
            if self.kv is not None:
                # the draft's KV lives at the same logical positions, so it
                # pages through the SAME allocator + block tables (shared
                # geometry, per-model page contents)
                self.draft_cache = init_paged_cache(
                    self.draft_cfg, max_slots, num_pages, page_size,
                    self.kv.max_blocks)
            else:
                self.draft_cache = init_cache(self.draft_cfg, max_slots,
                                              cache_capacity)
            if mesh is not None:
                self.draft_params = jax.device_put(
                    self.draft_params,
                    param_shardings(self.draft_cfg, self.rules, mesh))
                self.draft_cache = jax.device_put(
                    self.draft_cache, self._cache_shardings(self.draft_cfg))
        else:
            self.draft_cfg = self.draft_params = self.draft_cache = None

        # jit caches, keyed (kind, tlp, fc_variant, interpret) — see module
        # docstring for why the variant must be in the key.
        self._decode_jit: dict[tuple, Any] = {}
        self._prefill_jit: dict[tuple, Any] = {}

    # ------------------------------------------------------------------ API
    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)
        self._submit_t.setdefault(req.req_id, self._now())
        self.submit_iteration.setdefault(req.req_id, self.iteration)
        if self.journal is not None:
            self.journal.append("submit", req_id=req.req_id,
                                prompt=list(req.prompt),
                                max_new=int(req.max_new_tokens),
                                dl=req.deadline_s)
        if self.tracer.enabled:
            self.tracer.emit("submit", self.iteration, req_id=req.req_id,
                             prompt_len=len(req.prompt),
                             max_new=req.max_new_tokens)

    @property
    def active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def run(self, max_iterations: int = 10_000, *,
            abort_in_flight: bool = True) -> list[ServeResult]:
        while (self.queue or self.active_slots) and self.iteration < max_iterations:
            self.step()
        if abort_in_flight and self.active_slots and (
                self.iteration >= max_iterations):
            # iteration exhaustion must not drop in-flight requests on the
            # floor: return them honestly with their tokens-so-far and
            # drain their pages/reservations.  (Queued requests stay
            # queued — a later run() call picks them up.)  Tests that step
            # an engine incrementally pass ``abort_in_flight=False`` to
            # keep the in-flight state across run() calls.
            for s in list(self.active_slots):
                self._finish_slot(s, "aborted")
        return self.results

    def serve(self, arrivals, *, max_iterations: int = 100_000):
        """Continuous-batching serve loop: a generator of `TokenEvent`s over
        a LIVE arrival stream.

        ``arrivals`` is any iterable polled once per engine iteration;
        each ``next()`` yields the requests arriving at that iteration — a
        `ServeRequest`, a list of them, or None for a quiet tick — and
        exhaustion closes the arrival stream (the loop then drains the
        queue and in-flight slots and returns).  The iteration counter is
        the schedule's time axis: a trace replayed against the same engine
        configuration admits, decodes, and finishes deterministically.

        Every iteration the loop admits from the queue, advances newly
        admitted long prompts by one chunk wave MIXED with the ongoing
        decodes (TLP=1: one fused device program for both; speculative: the
        chunk wave plus the fused verify program — either way no prefill
        stall, no drain barrier), and yields each newly committed token as
        a `TokenEvent`.  A finished request yields a final event carrying
        its `ServeResult`, latencies stamped (queue delay / TTFT / TPOT —
        see serving/metrics.py; aggregate with `latency_summary`).

        Token streams are bit-identical to the offline ``submit()`` +
        ``run()`` oracle for the same request set, for every engine
        configuration (dense/paged, greedy/speculative, mesh, attn_pim) —
        gated in `benchmarks/engine_hotpath.py --arrivals`.  PR 6 semantics
        hold under live load: deadlines expire queued AND in-flight
        requests, `cancel()` works mid-stream, pool-pressure preemption
        requeues (the stream pauses, indices continue after re-admission,
        nothing is re-sent), and fault injection degrades the mixed wave
        onto the oracle path like any other poisoned step.

        Iteration exhaustion aborts in-flight requests honestly
        (``finished_reason="aborted"``, final events still delivered) —
        same contract as ``run()``.  Closing the generator early (a
        ``break``, ``close()``, or GC) does the same from its ``finally``:
        in-flight slots finish as "aborted" (results in ``self.results``;
        no events can be yielded during GeneratorExit), the page pool
        drains, queued requests stay queued, and the engine remains
        usable for a subsequent ``submit()`` + ``run()``.

        An exception propagating out of ``step()`` — `EngineCrashError`
        from the ``crash`` fault, `EngineStallError`, ... — is a
        (simulated) process death, NOT an early close: it re-raises with
        no abort cleanup and no journal finalization, so a journaled run
        recovers the in-flight requests via ``--resume`` instead of
        finding them durably marked "aborted".
        """
        arrivals = iter(arrivals)
        streamed: dict[int, int] = {}   # req_id -> tokens already yielded
        reported = len(self.results)    # results already turned into events
        stream_open = True
        completed = False
        crashed = False
        prev = self.stream_chunks
        self.stream_chunks = True
        try:
            while True:
                if stream_open:
                    try:
                        got = next(arrivals)
                    except StopIteration:
                        stream_open = False
                    else:
                        if got is None:
                            got = []
                        elif isinstance(got, ServeRequest):
                            got = [got]
                        for req in got:
                            self.submit(req)
                        self._arrived_this_step = len(got)
                if not stream_open and not (self.queue or self.active_slots):
                    completed = True
                    return
                if self.iteration >= max_iterations:
                    for s in list(self.active_slots):
                        self._finish_slot(s, "aborted")
                    yield from self._drain_events(streamed, reported)
                    completed = True
                    return
                self.step()
                # live slots first (mid-flight tokens), then finished
                # requests (their tail + the final event)
                for s in self.active_slots:
                    req = self.slot_req[s]
                    done = (req.done if isinstance(req, _ResumedRequest)
                            else [])
                    full = list(done) + self.slot_tokens[s]
                    sent = streamed.get(req.req_id, 0)
                    for i in range(sent, len(full)):
                        yield TokenEvent(req.req_id, full[i], i,
                                         self.iteration)
                    streamed[req.req_id] = max(sent, len(full))
                new_reported = len(self.results)
                yield from self._drain_events(streamed, reported)
                reported = new_reported
        except GeneratorExit:
            raise                 # early close: the finally abort applies
        except BaseException:
            # EngineCrashError / EngineStallError / anything else escaping
            # step() is a (simulated) process death, not an early close:
            # re-raise with NO cleanup and NO journal finalization, so the
            # in-flight requests stay recoverable (journal "aborted"
            # finishes here would make --resume skip them forever).
            crashed = True
            raise
        finally:
            self.stream_chunks = prev
            if not completed and not crashed:
                # the caller broke out of / close()d the generator
                # mid-stream: finish the in-flight slots honestly
                # ("aborted", tokens-so-far) so the page pool drains and
                # the engine stays reusable for a later submit()+run().
                # No events can be yielded during GeneratorExit — the
                # aborted ServeResults land in self.results instead.
                # Queued requests stay queued, same contract as run().
                for s in list(self.active_slots):
                    self._finish_slot(s, "aborted")

    def _drain_events(self, streamed: dict[int, int], reported: int):
        """Final-event tail for every result appended since `reported`:
        any not-yet-streamed tokens, then the completion event."""
        for res in self.results[reported:]:
            sent = streamed.pop(res.req_id, 0)
            for i in range(sent, len(res.tokens)):
                yield TokenEvent(res.req_id, res.tokens[i], i,
                                 self.iteration)
            yield TokenEvent(res.req_id, -1, len(res.tokens), self.iteration,
                             finished=True, reason=res.finished_reason,
                             result=res)

    def cancel(self, req_id: int) -> bool:
        """Cancel a queued or in-flight request: it finishes with
        ``finished_reason="cancelled"`` and its tokens-so-far, and its
        pages/reservations drain.  Returns False when no pending request
        carries `req_id` (already finished, or never submitted)."""
        for i, req in enumerate(self.queue):
            if req.req_id == req_id:
                self.queue.pop(i)
                self._journal_cancel(req_id)
                self._emit(req, [], "cancelled")
                return True
        for s in self.active_slots:
            if self.slot_req[s].req_id == req_id:
                self._journal_cancel(req_id)
                self._finish_slot(s, "cancelled")
                return True
        return False

    def _journal_cancel(self, req_id: int) -> None:
        if self.journal is not None:
            self.journal.append("cancel", req_id=req_id, it=self.iteration)

    # ----------------------------------------------------------- durability
    def _journal_commits(self) -> None:
        """End-of-step WAL flush: one commit record (delta tokens, total,
        remaining token budget, remaining deadline) per live slot that
        committed tokens this iteration.  Runs before `serve()` yields the
        step's TokenEvents, so a streamed token is always at least as
        durable as the journal's flush policy."""
        now = self._now()
        for s in self.active_slots:
            req = self.slot_req[s]
            done = req.done if isinstance(req, _ResumedRequest) else []
            full = list(done) + self.slot_tokens[s]
            prev = self._journal_done.get(req.req_id, 0)
            if len(full) <= prev:
                continue
            dl = getattr(req, "deadline_s", None)
            rem_dl = None
            if dl is not None:
                t0 = self._submit_t.get(req.req_id)
                rem_dl = dl if t0 is None else dl - (now - t0)
            self.journal.append(
                "commit", req_id=req.req_id, toks=full[prev:], n=len(full),
                rem=int(self.slot_budget[s]) - len(self.slot_tokens[s]),
                dl=rem_dl, it=self.iteration)
            self._journal_done[req.req_id] = len(full)

    def snapshot(self, path: str | None = None) -> dict:
        """Host-side logical state only — queue order, per-request
        ``(prompt, committed tokens, remaining token budget, remaining
        deadline)``, the admission counter — NEVER device arrays: the KV
        cache, block tables, and jit caches are all recomputable, because
        `restore()` re-admits unfinished work through the `_ResumedRequest`
        path and chunked prefill rebuilds the KV bit-identically.
        Unfinished work is listed in recovery order: in-flight slots
        (oldest admission first), then the queue.  Deadlines are stored as
        the REMAINING monotonic delta so a restart neither resets nor
        instantly expires them.  With `path`, also writes the snapshot
        atomically (see `journal.write_snapshot`)."""
        now = self._now()

        def rem_dl(req):
            dl = getattr(req, "deadline_s", None)
            if dl is None:
                return None
            t0 = self._submit_t.get(req.req_id)
            return dl if t0 is None else dl - (now - t0)

        def entry(req, emitted, rem):
            if isinstance(req, _ResumedRequest):
                prompt = req.prompt[:req.orig_prompt_len]
                plen = req.orig_prompt_len
                done = list(req.done) + list(emitted)
            else:
                prompt, plen = list(req.prompt), len(req.prompt)
                done = list(emitted)
            return {"req_id": req.req_id, "prompt": list(prompt),
                    "done": done, "max_new": int(rem),
                    "deadline_s": rem_dl(req), "orig_prompt_len": plen}

        requests = [entry(self.slot_req[s], self.slot_tokens[s],
                          int(self.slot_budget[s]) - len(self.slot_tokens[s]))
                    for _, s in sorted((self.slot_seq[s], s)
                                       for s in self.active_slots)]
        requests += [entry(req, [], req.max_new_tokens)
                     for req in self.queue]
        all_ids = ([r.req_id for r in self.results]
                   + [e["req_id"] for e in requests])
        state = {
            "papi_snapshot": SNAPSHOT_VERSION,
            "iteration": self.iteration,
            "admit_seq": self._admit_seq,
            "next_req_id": max(all_ids, default=-1) + 1,
            "requests": requests,
            "finished": [{"req_id": r.req_id, "reason": r.finished_reason,
                          "tokens": list(r.tokens)} for r in self.results],
        }
        if path is not None:
            write_snapshot(path, state)
            if self.tracer.enabled:
                self.tracer.emit("journal", self.iteration, op="snapshot",
                                 path=str(path), requests=len(requests))
        return state

    def restore(self, path) -> dict:
        """Re-admit every unfinished request recorded in the snapshot or
        journal at `path` into THIS (freshly constructed) engine, through
        the PR 6 `_ResumedRequest` path: ``prompt + committed tokens``
        re-chunks through prefill bit-identically, so each recovered
        stream continues exactly where the journal left off.  Finished
        requests (including torn-tail cases whose committed prefix already
        exhausted the budget or hit eos) are never re-admitted — finishes
        stay exactly-once.  Deadlines resume with their remaining budget.
        Returns a summary dict (resumed / finished / torn_bytes)."""
        state = recover(path, eos_token=self.eos_token)
        now = self._now()
        for r in state.requests:
            self.queue.append(_ResumedRequest(
                req_id=r.req_id, prompt=list(r.prompt) + list(r.done),
                max_new_tokens=int(r.max_new), deadline_s=r.deadline_s,
                done=list(r.done), orig_prompt_len=r.orig_prompt_len))
            # the deadline survives as a REMAINING monotonic delta: rebase
            # the submit stamp to now so _deadline_expired sees exactly
            # the budget that was left at snapshot/crash time
            self._submit_t[r.req_id] = now
            self.submit_iteration.setdefault(r.req_id, self.iteration)
            self._journal_done[r.req_id] = len(r.done)
            if self.journal is not None:
                self.journal.append(
                    "resume", req_id=r.req_id, prompt=list(r.prompt),
                    done=list(r.done), max_new=int(r.max_new),
                    dl=r.deadline_s, plen=r.orig_prompt_len)
        self._admit_seq = max(self._admit_seq, state.admit_seq)
        summary = {"resumed": len(state.requests),
                   "finished": len(state.finished),
                   "records": state.records,
                   "torn_bytes": state.torn_bytes,
                   "next_req_id": state.next_req_id}
        if self.tracer.enabled:
            self.tracer.emit("recover", self.iteration, path=str(path),
                             **summary)
        log.info("restored %d unfinished request(s) from %s (%d already "
                 "finished, %d torn byte(s) discarded)",
                 summary["resumed"], path, summary["finished"],
                 summary["torn_bytes"])
        return summary

    # ------------------------------------------------------------- internals
    def _cache_shardings(self, cfg: ModelConfig):
        if self.kv is not None:
            return paged_cache_shardings(
                cfg, self.max_slots, self.kv.alloc.num_pages + 1,
                self.kv.page_size, self.kv.max_blocks, self.rules, self.mesh)
        return cache_shardings(cfg, self.max_slots, self.capacity,
                               self.rules, self.mesh)

    def _slot_pos(self, s: int) -> int:
        """Device cache position of live slot s (tokens of KV written).  The
        first output token comes from prefill, so its KV is written by the
        NEXT decode step: pos = prompt + generated - 1."""
        return int(self.slot_prompt[s]) + len(self.slot_tokens[s]) - 1

    def _sync_tables(self) -> None:
        """Push the host block tables into the cache pytrees the jitted
        steps consume.  `BlockTables.device()` caches until a row mutates,
        so this is an identity check + dict store on the no-change path."""
        if self.kv is None:
            return
        tables = self.kv.tables.device()
        if self.cache["block_tables"] is not tables:
            self.cache = dict(self.cache)
            self.cache["block_tables"] = tables
            if self.draft_cache is not None:
                self.draft_cache = dict(self.draft_cache)
                self.draft_cache["block_tables"] = tables

    def _fetch(self, *arrays):
        """Single device->host sync round-trip (counted).  Sharded arrays
        gather here — still one round trip from the host's point of view."""
        self.host_transfers += 1
        if self._sanitizer is not None:
            with self._sanitizer.allow_transfers():
                # papilint: allow-transfer(the engine's single counted sync point)
                got = jax.device_get(arrays)
        else:
            # papilint: allow-transfer(the engine's single counted sync point)
            got = jax.device_get(arrays)
        return got[0] if len(arrays) == 1 else got

    def _scope(self):
        """The mesh trace/dispatch scope: installs the logical->mesh rules
        so `shard()` constraints and the shard_map'd kernels resolve.  Every
        compiled entry point must be CALLED under it too (papi_linear and
        the attn hook read it at trace time, and tracing happens lazily on
        the first call of each (kind, tlp, variant) key)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self.rules, self.mesh)

    def _attn_scope(self):
        """The decode-attention implementation for every compiled entry
        point: the Pallas flash-decode kernels under ``attn_pim=True`` (any
        TLP — plain decode, speculative verify windows, and chunked-prefill
        waves all hit the windowed kernel; the paged XLA page-gather never
        traces), the XLA softmax path otherwise.  Like `_scope`, tracing
        reads this at first call, so every jitted CALL must run under it."""
        return attn_impl("pim" if self.attn_pim else "xla")

    def _jit_key(self, kind: str, tlp: int) -> tuple:
        return (kind, tlp, self.scheduler.fc_assignment, self.pim_interpret,
                self.attn_pim)

    def _call(self, key: tuple, fn, *args):
        """Dispatch one compiled program.  Every `_get_*` getter returns its
        jit-cache key alongside the executable so the dispatch can be timed
        under THAT key — the per-variant timing table is exactly what a
        measured-characterization scheduler consumes (ROADMAP).  Under an
        enabled tracer the wall time is measured around
        `jax.block_until_ready`; untraced this is the bare call (no block:
        async dispatch is preserved)."""
        if self.tracer.enabled:
            return self.tracer.timed_call(key, fn, *args)
        return fn(*args)

    def _get_decode(self, which: str):
        """Legacy (unfused) per-call decode step."""
        tlp = 1 if which == "draft" else (self.spec_len if which == "verify" else 1)
        key = self._jit_key(which, tlp)
        if key not in self._decode_jit:
            cfg = self.draft_cfg if which == "draft" else self.cfg
            fn = partial(decode_step, cfg)
            self._decode_jit[key] = jax.jit(fn)
        return key, self._decode_jit[key]

    def _get_plain_fused(self):
        """Fused plain decode: decode_step + greedy in one device program, so
        the only host transfer is the [slots] token vector (plus the
        device-side finite-logits flag riding in the same fetch)."""
        key = self._jit_key("plain_fused", 1)
        if key not in self._decode_jit:
            cfg = self.cfg

            def plain_step(params, cache, last, fault):
                logits, cache = decode_step(cfg, params, cache, last[:, None])
                logits = _inject_fault(logits, fault)
                bad = ~jnp.all(jnp.isfinite(logits))
                return greedy(logits[:, -1]), bad, cache

            self._decode_jit[key] = jax.jit(plain_step)
        return key, self._decode_jit[key]

    def _get_spec_fused(self):
        """Fused speculative iteration: the k-step draft loop is a
        `jax.lax.scan`, the verify + accept-longest-prefix + cache rewind are
        vectorized device computation, and the host fetches a single
        (out, accepted, finished_eos) bundle."""
        key = self._jit_key("spec_fused", self.spec_len)
        if key not in self._decode_jit:
            cfg, dcfg = self.cfg, self.draft_cfg
            k, eos = self.spec_len, self.eos_token

            def spec_step(params, draft_params, cache, draft_cache, last,
                          fault):
                # 1) draft proposes autoregressively.  It runs k steps — the
                # extra step writes KV for the window's final token, keeping
                # the two caches in lockstep when the full window is accepted.
                def draft_body(carry, _):
                    dc, tok = carry
                    logits, dc = decode_step(dcfg, draft_params, dc,
                                             tok[:, None])
                    nxt = greedy(logits[:, -1])
                    return (dc, nxt), nxt

                (draft_cache, _), props = jax.lax.scan(
                    draft_body, (draft_cache, last), None, length=k)
                # window rows: [last, props[0], ..., props[k-2]]  -> [slots, k]
                window = jnp.concatenate([last[None], props[:-1]], axis=0).T

                # 2) target verifies the window in ONE decode step (TLP = k)
                logits, cache = decode_step(cfg, params, cache, window)
                logits = _inject_fault(logits, fault)
                bad = ~jnp.all(jnp.isfinite(logits))
                target = greedy(logits)                           # [slots, k]

                # 3) accept longest matching prefix, rewind target cache to
                # the accepted position, resync the draft cache to it
                out, accepted = accept_speculative(window, target)
                cache = dict(cache)
                cache["pos"] = cache["pos"] - (k - accepted)
                draft_cache = dict(draft_cache)
                draft_cache["pos"] = jnp.minimum(draft_cache["pos"],
                                                 cache["pos"])
                in_window = jnp.arange(k)[None, :] < accepted[:, None]
                finished_eos = jnp.any((out == eos) & in_window, axis=1)
                return out, accepted, finished_eos, bad, cache, draft_cache

            self._decode_jit[key] = jax.jit(spec_step)
        return key, self._decode_jit[key]

    def _get_oracle(self, which: str):
        """Degraded-mode decode step: the tested XLA-attention / plain-FC
        oracle path, compiled once per model and NEVER fault-injected.  Its
        jit key is independent of the scheduler's fc assignment — it must
        always be the same executable the correctness suite validates."""
        # papilint: disable=PL003 (oracle pins attn/fc at dispatch; one executable by contract)
        key = ("oracle", which)
        if key not in self._decode_jit:
            cfg = self.draft_cfg if which == "draft" else self.cfg
            self._decode_jit[key] = jax.jit(partial(decode_step, cfg))
        return key, self._decode_jit[key]

    def _fault_code(self):
        """Per-iteration logits-fault code, passed as a TRACED int32 scalar
        so flipping it never retraces the fused programs.  Under
        ``fused=False`` the engine already runs the oracle path, so logits
        faults only apply to the fused programs."""
        if self.faults is None or not self.fused:
            return jnp.asarray(FAULT_NONE, jnp.int32)
        code = self.faults.logits_fault(self.iteration)
        if code != FAULT_NONE and self.tracer.enabled:
            self.tracer.emit("fault", self.iteration,
                             fault="nan" if code == FAULT_NAN else "inf")
        return jnp.asarray(code, jnp.int32)

    def _degraded_step(self):
        """Re-run a poisoned iteration on the oracle path: XLA attention,
        plain-PU linear, speculation clamped to a single plain decode step.
        Runs inside `_decode_all`'s ambient scopes — `attn_impl` and
        `fc_variant` are save/restore context managers, so nesting the
        oracle contexts here is safe.  When speculating, the draft cache
        advances one plain step too, keeping both KVs in lockstep for the
        next (healthy) speculative iteration."""
        self.degraded_steps += 1
        self._degraded_this_step = True
        if self.tracer.enabled:
            self.tracer.emit("degraded", self.iteration, mode="step")
        log.warning("non-finite logits at iteration %d: re-running the "
                    "step on the oracle path", self.iteration)
        last = jnp.asarray(self.slot_last)
        with attn_impl("xla"), fc_variant("pu"):
            okey, ofn = self._get_oracle("main")
            logits, self.cache = self._call(
                okey, ofn, self.params, self.cache, last[:, None])
            if self.spec_len > 1 and self.draft_cfg is not None:
                dkey, dfn = self._get_oracle("draft")
                _, self.draft_cache = self._call(
                    dkey, dfn, self.draft_params, self.draft_cache,
                    last[:, None])
            # papilint: allow-transfer(degraded re-run commits its token)
            nxt_h = self._fetch(greedy(logits[:, -1]))
        return (np.asarray(nxt_h)[:, None].astype(np.int32),
                np.ones(self.max_slots), None)

    def _get_prefill(self, which: str):
        cfg = self.draft_cfg if which == "draft" else self.cfg
        # admission usually runs outside any fc_variant context ("pu"), but
        # papi_linear reads the AMBIENT variant at trace time — key on it so
        # a caller-wrapped engine never reuses a stale executable.  The attn
        # impl is keyed too: chunk waves trace the windowed Pallas kernel
        # under attn_pim.
        key = (which, current_fc_variant(), current_fc_interpret(),
               self.attn_pim)
        if key not in self._prefill_jit:
            fn = prefill_to_pages if self.kv is not None else prefill_to_slots
            self._prefill_jit[key] = jax.jit(partial(fn, cfg))
        return key, self._prefill_jit[key]

    def _get_chunk(self, which: str):
        """Chunked-prefill continuation step (`models.prefill_chunk`): one
        fixed [max_slots, prefill_len] window through the decode path at
        each slot's running prompt offset.  Layout-agnostic — the cache
        pytree carries the block tables when paged."""
        cfg = self.draft_cfg if which == "draft" else self.cfg
        key = ("chunk_" + which, current_fc_variant(),
               current_fc_interpret(), self.attn_pim)
        if key not in self._prefill_jit:
            self._prefill_jit[key] = jax.jit(partial(prefill_chunk, cfg))
        return key, self._prefill_jit[key]

    # --------------------------------------- continuous batching (serve())
    def _get_wave(self, which: str):
        """The serve loop's mixed prefill/decode wave (`models.mixed_step`):
        prefill chunks and single-token decodes share one fixed-shape
        [max_slots, prefill_len] program.  The main wave folds fault
        injection + the finite-logits guard + the greedy argmax in with the
        logits (one fetchable (tokens, bad) bundle); the draft wave only
        advances the draft KV, nothing is fetched from it."""
        cfg = self.draft_cfg if which == "draft" else self.cfg
        key = ("wave_" + which, current_fc_variant(),
               current_fc_interpret(), self.attn_pim)
        if key not in self._prefill_jit:
            if which == "main":
                def wave(params, cache, toks, lens, pin_mask, pin_pos,
                         fault):
                    logits, cache = mixed_step(cfg, params, cache, toks,
                                               lens, pin_mask, pin_pos)
                    logits = _inject_fault(logits, fault)
                    bad = ~jnp.all(jnp.isfinite(logits))
                    return greedy(logits), bad, cache
            else:
                def wave(params, cache, toks, lens, pin_mask, pin_pos):
                    _, cache = mixed_step(cfg, params, cache, toks, lens,
                                          pin_mask, pin_pos)
                    return cache
            self._prefill_jit[key] = jax.jit(wave)
        return key, self._prefill_jit[key]

    def _get_oracle_wave(self):
        """Degraded-mode wave: the XLA-attention / plain-FC oracle, never
        fault-injected, keyed independently of the scheduler's assignment
        (same contract as `_get_oracle`)."""
        # papilint: disable=PL003 (oracle pins attn/fc at dispatch; one executable by contract)
        key = ("oracle_wave",)
        if key not in self._prefill_jit:
            cfg = self.cfg

            def wave(params, cache, toks, lens, pin_mask, pin_pos):
                logits, cache = mixed_step(cfg, params, cache, toks, lens,
                                           pin_mask, pin_pos)
                return greedy(logits), cache

            self._prefill_jit[key] = jax.jit(wave)
        return key, self._prefill_jit[key]

    def _prefilling_slots(self) -> list[int]:
        """Slots mid-chunked-prefill (serve() only: offline admission always
        completes a prompt's waves before returning)."""
        return [s for s in self.active_slots
                if int(self.slot_offset[s]) < int(self.slot_prompt[s])]

    def _tokens_written(self, s: int) -> int:
        """KV tokens live slot `s` has actually committed: the chunk
        frontier while mid-prefill, the decode position after."""
        off = int(self.slot_offset[s])
        return off if off < int(self.slot_prompt[s]) else self._slot_pos(s)

    def _wave_rows(self, prefilling: list[int]):
        """Build one chunk wave over the mid-prefill slots: each advances by
        one (ragged-tail-masked) window from its running offset.  Returns
        the host-side row arrays plus the slots whose prompt this wave
        completes (their logits row is the request's first output token).
        ``pin`` re-anchors each prefilling row's cache position to the
        host-tracked offset — mid-prefill slots ride every OTHER dispatched
        program as masked garbage rows whose device `pos` drifts."""
        ctoks = np.zeros((self.max_slots, self.prefill_len), np.int32)
        clens = np.zeros(self.max_slots, np.int32)
        pin = np.zeros(self.max_slots, bool)
        pin_pos = np.zeros(self.max_slots, np.int32)
        finals: list[int] = []
        for s in prefilling:
            req = self.slot_req[s]
            off, plen = int(self.slot_offset[s]), int(self.slot_prompt[s])
            n = min(plen - off, self.prefill_len)
            ctoks[s, :n] = req.prompt[off:off + n]
            clens[s] = n
            pin[s] = True
            pin_pos[s] = off
            if off + n == plen:
                finals.append(s)
        return ctoks, clens, pin, pin_pos, finals

    def _finalize_first_tokens(self, finals: list[int],
                               nxt_h: np.ndarray) -> None:
        """A wave just completed these slots' prompts: commit each first
        output token (same instant-finish semantics as offline admission —
        <eos> or a 1-token budget frees the slot for the next iteration's
        admission)."""
        for s in finals:
            req = self.slot_req[s]
            tok = int(nxt_h[s])
            self._note_first_token(req.req_id)
            self.slot_tokens[s] = [tok]
            self.slot_last[s] = tok
            if tok == self.eos_token or self.slot_budget[s] <= 1:
                reason = "eos" if tok == self.eos_token else "length"
                self._emit(req, [tok], reason, slot=s)
                self.slot_req[s] = None
                self.slot_tokens[s] = []
                self.slot_last[s] = 0
                if self.kv is not None:
                    self.kv.release(s)

    def _ensure_wave_pages(self, prefilling: list[int],
                           clens: np.ndarray) -> None:
        """Map the pages this wave's chunks write (serve() admitted with
        only chunk 0 mapped).  Cannot fail: the admission reservation
        covers the full prompt + budget + window."""
        if self.kv is None:
            return
        for s in prefilling:
            self.kv.ensure(s, int(self.slot_offset[s]) + int(clens[s]))

    def _chunk_wave(self, prefilling: list[int]) -> None:
        """Speculative serve iterations run the prefill chunks as their own
        wave (prefill rows only) and let the decodes ride the fused
        speculative program right after — two dispatches, still zero
        prefill stall.  Runs under the ambient ("pu") FC variant exactly
        like offline admission chunks, so first tokens are bit-identical to
        the offline oracle."""
        ctoks, clens, pin, pin_pos, finals = self._wave_rows(prefilling)
        self._ensure_wave_pages(prefilling, clens)
        self._sync_tables()
        ct, cl = jnp.asarray(ctoks), jnp.asarray(clens)
        pm, pp = jnp.asarray(pin), jnp.asarray(pin_pos)
        with self._scope(), self._attn_scope():
            wkey, wfn = self._get_wave("main")
            nxt, bad, cache2 = self._call(
                wkey, wfn, self.params, self.cache, ct, cl, pm, pp,
                jnp.asarray(FAULT_NONE, jnp.int32))
            self.cache = cache2
            if self.draft_cfg is not None:
                dkey, dfn = self._get_wave("draft")
                self.draft_cache = self._call(
                    dkey, dfn, self.draft_params, self.draft_cache,
                    ct, cl, pm, pp)
        for s in prefilling:
            self.slot_offset[s] += int(clens[s])
        if finals:
            # papilint: allow-transfer(first tokens of finishing chunks)
            nxt_h, _ = self._fetch(nxt, bad)
            self._finalize_first_tokens(finals, np.asarray(nxt_h))

    def _mixed_wave_iteration(self, prefilling: list[int],
                              decoding: list[int]):
        """The tentpole TLP=1 serve iteration: ongoing decodes (chunks of
        length 1 holding each slot's last token) and new requests' prefill
        chunk waves run in ONE fused device program — no prefill stall, one
        dispatch + one fetch per iteration.  Returns the `_decode_all`-shaped
        (out, accepted, finished) bundle for the decoding slots."""
        ctoks, clens, pin, pin_pos, finals = self._wave_rows(prefilling)
        chunk_lens = clens.copy()        # prefill rows only, for the draft
        for s in decoding:
            ctoks[s, 0] = self.slot_last[s]
            clens[s] = 1
        self._ensure_wave_pages(prefilling, chunk_lens)
        if self.kv is not None:
            for s in decoding:
                self.kv.ensure(s, self._slot_pos(s) + 1)
        self._sync_tables()
        ct, cl = jnp.asarray(ctoks), jnp.asarray(clens)
        pm, pp = jnp.asarray(pin), jnp.asarray(pin_pos)
        variant = self.scheduler.fc_assignment
        with self._scope(), \
                fc_variant(variant, interpret=self.pim_interpret), \
                self._attn_scope():
            wkey, wfn = self._get_wave("main")
            nxt, bad, cache2 = self._call(
                wkey, wfn, self.params, self.cache, ct, cl, pm, pp,
                self._fault_code())
            if self.draft_cfg is not None and prefilling:
                # the draft's KV covers the prompt positions (chunk rows
                # only — the TLP=1 decode path never advances the draft)
                dkey, dfn = self._get_wave("draft")
                self.draft_cache = self._call(
                    dkey, dfn, self.draft_params, self.draft_cache, ct,
                    jnp.asarray(chunk_lens), pm, pp)
            # papilint: allow-transfer(the wave's one token+fault fetch)
            nxt_h, bad_h = self._fetch(nxt, bad)
            if bad_h:
                # non-finite logits: drop the poisoned wave (cache2 never
                # assigned) and re-run the SAME wave on the oracle path
                out_h = self._degraded_wave(ct, cl, pm, pp)
            else:
                self.cache = cache2
                out_h = np.asarray(nxt_h)
        for s in prefilling:
            self.slot_offset[s] += int(chunk_lens[s])
        self._finalize_first_tokens(finals, out_h)
        return (out_h[:, None].astype(np.int32), np.ones(self.max_slots),
                None)

    def _degraded_wave(self, ct, cl, pm, pp) -> np.ndarray:
        """Oracle re-run of a poisoned mixed wave (the wave twin of
        `_degraded_step`): XLA attention, plain-PU FC, never injected."""
        self.degraded_steps += 1
        self._degraded_this_step = True
        if self.tracer.enabled:
            self.tracer.emit("degraded", self.iteration, mode="wave")
        log.warning("non-finite logits at iteration %d: re-running the "
                    "mixed wave on the oracle path", self.iteration)
        with attn_impl("xla"), fc_variant("pu"):
            okey, ofn = self._get_oracle_wave()
            nxt, self.cache = self._call(
                okey, ofn, self.params, self.cache, ct, cl, pm, pp)
            # papilint: allow-transfer(oracle wave re-run commits tokens)
            return np.asarray(self._fetch(nxt))

    def _admit(self) -> int:
        """Mixed continuous batching: fill free slots from the queue, one
        compiled `prefill_to_slots` call per admission wave (fixed-shape
        batch padded to max_slots, so the call compiles exactly once).  A
        request that finishes instantly at admission (first token is <eos>,
        or a 1-token budget) frees its slot for the NEXT wave, so the queue
        keeps draining within this step exactly like the seed's slot-reuse
        loop did."""
        self._deferred_head = None
        if (self.queue and self.faults is not None
                and self.faults.admission_blocked(self.iteration)):
            # injected allocator admission failure: the whole wave defers
            # (queue order kept) and the deferral-age / preemption /
            # watchdog machinery sees it like genuine pool pressure
            self._deferred_head = self.queue[0].req_id
            if self.tracer.enabled:
                self.tracer.emit("fault", self.iteration, fault="admit",
                                 req_id=self._deferred_head)
            return 0
        admitted = 0
        while True:
            wave_admitted, instant_finish = self._admit_wave()
            admitted += wave_admitted
            if not (instant_finish and self.queue):
                return admitted

    def _reject(self, req: ServeRequest) -> None:
        self._emit(req, [], "rejected")

    # ------------------------------------------------- failure-model helpers
    def _now(self) -> float:
        """Deadline clock (monotonic); tests monkeypatch this to expire
        deadlines without sleeping."""
        return time.monotonic()

    def _note_first_token(self, req_id: int) -> None:
        """TTFT stamp: the request's first output token just materialized.
        setdefault — a preempted request's re-admission produces a
        CONTINUATION token through the same code path, and the original
        first-token stamp must survive it."""
        if req_id not in self._first_tok_t:
            self._first_tok_t[req_id] = self._now()
            self.first_token_iteration.setdefault(req_id, self.iteration)
            if self.tracer.enabled:
                self.tracer.emit("first_token", self.iteration,
                                 req_id=req_id)

    def _latency_fields(self, req_id: int, n_tokens: int) -> dict:
        """Per-request latency bundle for the ServeResult (see
        serving/metrics.py for the metric definitions).  Missing phases
        (never admitted / never produced a token) yield None, not 0 — the
        summary excludes them instead of skewing percentiles."""
        now = self._now()
        t0, i0 = self._submit_t.get(req_id), self.submit_iteration.get(req_id)
        ta, ia = self._admit_t.get(req_id), self.admit_iteration.get(req_id)
        tf = self._first_tok_t.get(req_id)
        i_f = self.first_token_iteration.get(req_id)
        return dict(
            queue_delay_s=(ta - t0) if (t0 is not None and ta is not None)
            else None,
            ttft_s=(tf - t0) if (t0 is not None and tf is not None) else None,
            # no inter-token gap exists below 2 tokens: None (excluded from
            # the summary, which counts contributors per metric), not a
            # fake 0.0 dragging the percentiles down
            tpot_s=(((now - tf) / (n_tokens - 1)) if n_tokens > 1 else None)
            if tf is not None else None,
            queue_delay_iters=(ia - i0)
            if (i0 is not None and ia is not None) else None,
            ttft_iters=(i_f - i0)
            if (i0 is not None and i_f is not None) else None,
        )

    def _emit(self, req, tokens: Sequence[int], reason: str,
              slot: int | None = None) -> None:
        """Append the caller-visible result for `req`.  A preempted request
        re-entered admission as a `_ResumedRequest` whose prompt carries its
        own earlier output — reassemble the original stream here."""
        if isinstance(req, _ResumedRequest):
            toks, plen = req.done + list(tokens), req.orig_prompt_len
        else:
            toks, plen = list(tokens), len(req.prompt)
        if self.journal is not None:
            # WAL discipline: the finish record (carrying the tail since
            # the last commit) goes durable BEFORE the result is
            # externalized, so a durable consumer sees finishes
            # exactly-once across a crash
            prev = self._journal_done.pop(req.req_id, 0)
            self.journal.append("finish", req_id=req.req_id, reason=reason,
                                toks=toks[prev:], n=len(toks),
                                it=self.iteration)
        self.results.append(ServeResult(
            req.req_id, toks, plen, self.iteration, reason,
            **self._latency_fields(req.req_id, len(toks))))
        if self.tracer.enabled:
            self.tracer.emit("finish", self.iteration, req_id=req.req_id,
                             reason=reason, tokens=len(toks), slot=slot)
        if reason not in ("eos", "length"):
            # unhappy finishes (timeout / cancelled / rejected / aborted)
            # are operational signals, not errors — INFO
            log.info("request %d finished: %s (%d tokens)",
                     req.req_id, reason, len(toks))

    def _finish_slot(self, s: int, reason: str) -> None:
        """Finish live slot `s` outside the normal eos/length path (timeout,
        cancel, abort): emit tokens-so-far and drain the slot's pages."""
        self._emit(self.slot_req[s], self.slot_tokens[s], reason, slot=s)
        self.slot_req[s] = None
        self.slot_tokens[s] = []
        self.slot_last[s] = 0
        if self.kv is not None:
            self.kv.release(s)

    def _deadline_expired(self, req) -> bool:
        dl = getattr(req, "deadline_s", None)
        if dl is None:
            return False
        t0 = self._submit_t.get(req.req_id)
        return t0 is not None and self._now() - t0 > dl

    def _expire_deadlines(self) -> None:
        still_queued = [r for r in self.queue if not self._deadline_expired(r)]
        if len(still_queued) != len(self.queue):
            for req in self.queue:
                if self._deadline_expired(req):
                    self._emit(req, [], "timeout")
            self.queue = still_queued
        for s in self.active_slots:
            if self._deadline_expired(self.slot_req[s]):
                self._finish_slot(s, "timeout")

    def _should_preempt(self) -> bool:
        """Pool-pressure trigger: the head has deferred `preempt_after`
        consecutive iterations, or the pool occupancy crossed
        `preempt_watermark` (fraction of usable pages mapped) while a
        deferral is pending.  Dense admission never defers, so preemption
        is a paged-layout mechanism."""
        if self.kv is None or self._defer_age < 1:
            return False
        if self.preempt_after is not None and (
                self._defer_age >= self.preempt_after):
            return True
        if self.preempt_watermark is not None:
            alloc = self.kv.alloc
            return alloc.mapped_count >= self.preempt_watermark * alloc.num_pages
        return False

    def _preempt_one(self) -> bool:
        """Preempt the YOUNGEST in-flight request (highest admission
        sequence number): release its pages and requeue it at the back as
        `prompt + tokens-so-far`, which chunked admission recomputes
        bit-identically.  The oldest in-flight request is never preempted
        — it always runs to completion, so the pool always drains toward
        the deferring head and forward progress is guaranteed (with a
        single in-flight request there is nothing younger, so the head
        simply waits for it to finish)."""
        live = sorted((self.slot_seq[s], s) for s in self.active_slots)
        if len(live) < 2:
            return False
        victim = live[-1][1]
        req = self.slot_req[victim]
        emitted = self.slot_tokens[victim]
        if isinstance(req, _ResumedRequest):
            done = req.done + list(emitted)
            base_prompt = req.prompt[:req.orig_prompt_len]
            plen = req.orig_prompt_len
        else:
            done = list(emitted)
            base_prompt = list(req.prompt)
            plen = len(req.prompt)
        self.queue.append(_ResumedRequest(
            req_id=req.req_id,
            prompt=base_prompt + done,
            max_new_tokens=int(self.slot_budget[victim]) - len(emitted),
            deadline_s=getattr(req, "deadline_s", None),
            done=done,
            orig_prompt_len=plen,
        ))
        self.slot_req[victim] = None
        self.slot_tokens[victim] = []
        self.slot_last[victim] = 0
        if self.kv is not None:
            self.kv.release(victim)
        self.preemptions += 1
        self.preempted_ids.add(req.req_id)
        if self.journal is not None:
            self.journal.append("preempt", req_id=req.req_id,
                                done=len(done), it=self.iteration)
        if self.tracer.enabled:
            self.tracer.emit("preempt", self.iteration, req_id=req.req_id,
                             slot=victim, done=len(done))
        log.info("preempted request %d from slot %d (%d tokens done, "
                 "deferral age %d)", req.req_id, victim, len(done),
                 self._defer_age)
        return True

    def _snapshot(self) -> dict:
        """Diagnostic state bundle carried by the structured errors."""
        snap = {
            "iteration": self.iteration,
            "queue": [r.req_id for r in self.queue],
            "deferred_head": self._defer_head,
            "deferral_age": self._defer_age,
            "active": {s: self.slot_req[s].req_id
                       for s in self.active_slots},
            "slot_budget": {s: int(self.slot_budget[s])
                            for s in self.active_slots},
            "preemptions": self.preemptions,
            "degraded_steps": self.degraded_steps,
            "stalled_iterations": self._stalled,
        }
        if self.kv is not None:
            snap["pool"] = self.kv.alloc.snapshot()
        return snap

    def _watchdog(self, progress: bool) -> None:
        if progress:
            self._stalled = 0
            return
        self._stalled += 1
        if (self.stall_limit is not None
                and (self.queue or self.active_slots)
                and self._stalled >= self.stall_limit):
            snap = self._snapshot()
            # the snapshot rides the trace too, so a post-mortem does not
            # depend on the exception propagating to something that logs it
            if self.tracer.enabled:
                self.tracer.emit("stall", self.iteration, snapshot=snap)
            log.error("engine stalled for %d iterations at iteration %d "
                      "(queue=%s)", self._stalled, self.iteration,
                      snap["queue"])
            raise EngineStallError(
                f"engine made no progress for {self._stalled} consecutive "
                f"iterations at iteration {self.iteration} "
                f"(queue={snap['queue']}, deferral_age={self._defer_age}, "
                f"pool={snap.get('pool')})", snap)

    def _check_invariants(self) -> None:
        if not (self.debug_invariants and self.kv is not None):
            return
        try:
            self.kv.alloc.check()
        except AssertionError as err:
            raise AllocatorInvariantError(
                f"page-pool invariant violated at iteration "
                f"{self.iteration}: {err}", self._snapshot()) from err

    def _mark_admitted(self, slot: int, req) -> None:
        """Admission-order bookkeeping: the preemption victim policy sorts
        on `slot_seq`, and the first-admission iteration feeds the
        admission-delay numbers the --pressure benchmark gates."""
        self._admit_seq += 1
        self.slot_seq[slot] = self._admit_seq
        self.admit_iteration.setdefault(req.req_id, self.iteration)
        self._admit_t.setdefault(req.req_id, self._now())
        if self.journal is not None:
            # the admission-CLAMPED budget: re-admission after recovery
            # clamps the same way preemption does, so replay must see the
            # effective value, not the caller's max_new_tokens
            self.journal.append("admit", req_id=req.req_id, slot=slot,
                                budget=int(self.slot_budget[slot]),
                                it=self.iteration)
        if self.tracer.enabled:
            self.tracer.emit("admit", self.iteration, req_id=req.req_id,
                             slot=slot, prompt_len=len(req.prompt))

    def _admit_wave(self) -> tuple[int, bool]:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        batch_rows: list[tuple[int, ServeRequest]] = []
        window = max(self.spec_len, 1)
        while self.queue and free:
            req = self.queue[0]
            p = len(req.prompt)        # FULL prompt — never truncated
            if p > self.prefill_len and not self._can_chunk:
                # SSM/hybrid state cannot mask the garbage tail of a chunk
                # window, so stateful families stay single-window; reject
                # honestly instead of silently dropping the prompt head
                self.queue.pop(0)
                self._reject(req)
                continue
            if self.kv is not None:
                # page-budgeted admission: a request enters iff pages for
                # the FULL prompt + max_new_tokens + a speculative window
                # are available.  The prompt's pages are mapped up front
                # (every chunk scatters straight onto them); the rest of
                # the budget is reserved and mapped lazily.  Per-request
                # length is bounded by the POOL, not a per-slot slab.
                cap = self.kv.max_context - p - window
                if cap < 1:
                    self.queue.pop(0)
                    self._reject(req)
                    continue
                want = max(1, min(req.max_new_tokens, cap))
                if not self.kv.can_admit(p + want + window):
                    # pool busy — defer (the queue keeps order) instead of
                    # rejecting.  The deferral is noted so step() can age
                    # it and trigger pool-pressure preemption; absent that,
                    # the reservation math still guarantees this clears
                    # once running requests finish.
                    self._deferred_head = req.req_id
                    break
                self.queue.pop(0)
                slot = free.pop(0)
                # serve() maps only chunk 0's pages up front and lets each
                # later wave map its own chunk (admission under LIVE
                # pressure: the full budget is still reserved — ensure()
                # cannot fail — but the pool watermark follows the prefill
                # frontier instead of spiking to the whole prompt at once)
                initial = (min(p, self.prefill_len) if self.stream_chunks
                           else p)
                self.kv.admit(slot, p + want + window, initial)
                self.slot_budget[slot] = want
                self._mark_admitted(slot, req)
                batch_rows.append((slot, req))
                continue
            self.queue.pop(0)
            # never let a request outgrow its slot's KV capacity: the budget
            # reserves a full speculative window past the last new token.
            # A prompt the slab cannot hold at all is rejected — honestly,
            # not truncated.
            budget = self.capacity - p - window
            if budget < 1:
                # cannot emit even one token without overflowing the slot
                self._reject(req)
                continue
            slot = free.pop(0)
            self.slot_budget[slot] = max(1, min(req.max_new_tokens, budget))
            self._mark_admitted(slot, req)
            batch_rows.append((slot, req))
        if not batch_rows:
            return 0, False

        # ---- chunk 0: the compiled fixed-shape prefill (positions 0..P-1)
        tokens = np.zeros((self.max_slots, self.prefill_len), np.int32)
        lens = np.ones(self.max_slots, np.int32)
        src = np.full(self.max_slots, -1, np.int32)
        for row, (slot, req) in enumerate(batch_rows):
            p0 = min(len(req.prompt), self.prefill_len)
            tokens[row, :p0] = req.prompt[:p0]
            lens[row] = p0
            src[slot] = row
            self.slot_prompt[slot] = len(req.prompt)
        batch = {"tokens": jnp.asarray(tokens),
                 "prompt_lens": jnp.asarray(lens)}
        src_dev = jnp.asarray(src)
        self._sync_tables()   # paged: admitted rows just mapped their pages
        with self._scope(), self._attn_scope():
            pkey, pfn = self._get_prefill("main")
            first, self.cache = self._call(
                pkey, pfn, self.params, batch, self.cache, src_dev)
            if self.draft_cfg is not None:
                dkey, dfn = self._get_prefill("draft")
                _, self.draft_cache = self._call(
                    dkey, dfn, self.draft_params, batch, self.draft_cache,
                    src_dev)
        admitted = 0
        if self.stream_chunks:
            # ---- continuous batching: a prompt longer than the window does
            # NOT stall this admission — the slot enters mid-prefill
            # (slot_offset < slot_prompt) and step() advances it one chunk
            # wave per iteration, MIXED with the ongoing decodes, until its
            # final chunk produces the first token.  Short prompts finalize
            # right here exactly like offline admission.
            long_rows = [(slot, req) for slot, req in batch_rows
                         if len(req.prompt) > self.prefill_len]
            for slot, req in long_rows:
                self.slot_req[slot] = req
                self.slot_tokens[slot] = []
                self.slot_offset[slot] = self.prefill_len
                admitted += 1              # counts toward RLP
            batch_rows = [(slot, req) for slot, req in batch_rows
                          if len(req.prompt) <= self.prefill_len]
            if not batch_rows:
                return admitted, False
            # papilint: allow-transfer(admission wave's first tokens)
            first_h = np.array(self._fetch(first))
        else:
            # ---- chunks 1..: prompts longer than the window continue
            # through the fixed-shape chunk step at their running offsets.
            # Every wave advances each pending slot by one
            # (ragged-tail-masked) window; a slot's first output token comes
            # from its FINAL chunk's logits.  Nothing host-side depends on a
            # wave's result (tokens come from req.prompt), so all waves
            # dispatch back-to-back and the whole admission costs ONE
            # device->host sync at the end.
            pending = {slot: req for slot, req in batch_rows
                       if len(req.prompt) > self.prefill_len}
            offs = {slot: self.prefill_len for slot in pending}
            wave_finals: list[tuple[Any, list[int]]] = []
            while pending:
                ctoks = np.zeros((self.max_slots, self.prefill_len), np.int32)
                clens = np.zeros(self.max_slots, np.int32)
                final: list[int] = []
                for slot, req in list(pending.items()):
                    n = min(len(req.prompt) - offs[slot], self.prefill_len)
                    ctoks[slot, :n] = req.prompt[offs[slot]:offs[slot] + n]
                    clens[slot] = n
                    offs[slot] += n
                    if offs[slot] == len(req.prompt):
                        final.append(slot)
                        del pending[slot]
                ct, cl = jnp.asarray(ctoks), jnp.asarray(clens)
                with self._scope(), self._attn_scope():
                    ckey, cfn = self._get_chunk("main")
                    nxt, self.cache = self._call(
                        ckey, cfn, self.params, self.cache, ct, cl)
                    if self.draft_cfg is not None:
                        # the draft's KV must cover the same prompt positions
                        dkey, dfn = self._get_chunk("draft")
                        _, self.draft_cache = self._call(
                            dkey, dfn, self.draft_params, self.draft_cache,
                            ct, cl)
                if final:
                    wave_finals.append((nxt, final))
            # papilint: allow-transfer(one batched sync for all waves)
            got = self._fetch(first, *(nxt for nxt, _ in wave_finals))
            if wave_finals:
                first_h = np.array(got[0])
                for (_, final), nxt_h in zip(wave_finals, got[1:]):
                    for slot in final:
                        first_h[slot] = int(nxt_h[slot])
            else:
                first_h = np.array(got)

        instant_finish = False
        for slot, req in batch_rows:
            self.slot_offset[slot] = len(req.prompt)
            tok = int(first_h[slot])
            self._note_first_token(req.req_id)
            self.slot_tokens[slot] = [tok]
            self.slot_last[slot] = tok
            # prefill already produced the first output token
            if tok == self.eos_token or self.slot_budget[slot] <= 1:
                reason = "eos" if tok == self.eos_token else "length"
                self._emit(req, [tok], reason, slot=slot)
                self.slot_last[slot] = 0   # slot stays available
                if self.kv is not None:
                    self.kv.release(slot)
                instant_finish = True
            else:
                self.slot_req[slot] = req
                admitted += 1              # counts toward RLP
        return admitted, instant_finish

    def _decode_all(self) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
        """One decoding iteration for all slots.  Returns (new token matrix
        [slots, <=tlp], accepted counts [slots], eos-finished mask|None)."""
        variant = self.scheduler.fc_assignment
        tlp = self.spec_len
        with self._scope(), \
                fc_variant(variant, interpret=self.pim_interpret), \
                self._attn_scope():
            if tlp <= 1 or self.draft_cfg is None:
                last = jnp.asarray(self.slot_last)
                if self.fused:
                    fkey, ffn = self._get_plain_fused()
                    nxt, bad, cache2 = self._call(
                        fkey, ffn, self.params, self.cache, last,
                        self._fault_code())
                    # papilint: allow-transfer(the iteration's one fetch)
                    nxt_h, bad_h = self._fetch(nxt, bad)
                    if bad_h:
                        # non-finite logits: drop the poisoned step (the
                        # returned cache is never assigned) and re-run on
                        # the oracle path
                        return self._degraded_step()
                    self.cache = cache2
                else:
                    pkey, pfn = self._get_decode("plain")
                    logits, self.cache = self._call(
                        pkey, pfn, self.params, self.cache, last[:, None])
                    # papilint: allow-transfer(legacy unfused per-step fetch)
                    nxt_h = self._fetch(greedy(logits[:, -1]))
                return (np.asarray(nxt_h)[:, None].astype(np.int32),
                        np.ones(self.max_slots), None)
            if self.fused:
                return self._speculative_iteration_fused()
            return self._speculative_iteration_host()

    def _speculative_iteration_fused(self):
        """Device-resident draft/verify/accept: one transfer per iteration."""
        key, fn = self._get_spec_fused()
        out, accepted, fin, bad, cache, draft_cache = self._call(
            key, fn, self.params, self.draft_params, self.cache,
            self.draft_cache, jnp.asarray(self.slot_last), self._fault_code(),
        )
        # papilint: allow-transfer(the spec iteration's one bundle fetch)
        out_h, acc_h, fin_h, bad_h = self._fetch(out, accepted, fin, bad)
        if bad_h:
            # non-finite verify logits: neither cache is assigned (both
            # still hold the pre-step state), and the iteration degrades to
            # a single oracle decode step (spec window clamped to 1)
            return self._degraded_step()
        self.cache, self.draft_cache = cache, draft_cache
        return (np.asarray(out_h), np.asarray(acc_h).astype(np.float64),
                np.asarray(fin_h))

    def _speculative_iteration_host(self):
        """The seed's per-step host loop — the reference implementation the
        fused path is validated against (and the benchmark's baseline)."""
        k = self.spec_len
        draft_key, draft_fn = self._get_decode("draft")
        # 1) draft proposes k-1 tokens autoregressively (k steps: the extra
        # step writes KV for the window's final token)
        proposals = [self.slot_last.copy()]
        last = jnp.asarray(self.slot_last[:, None])
        for _ in range(k):
            logits, self.draft_cache = self._call(
                draft_key, draft_fn, self.draft_params, self.draft_cache,
                last
            )
            nxt = greedy(logits[:, -1])
            # papilint: allow-transfer(legacy host-spec baseline, per draft step)
            proposals.append(np.asarray(self._fetch(nxt)))
            last = nxt[:, None]
        window = np.stack(proposals[:k], axis=1)          # [slots, k]

        # 2) target verifies the window in ONE decode step (TLP = k)
        vkey, vfn = self._get_decode("verify")
        logits, self.cache = self._call(
            vkey, vfn, self.params, self.cache, jnp.asarray(window)
        )
        # papilint: allow-transfer(legacy host-spec verify fetch)
        target = np.asarray(self._fetch(greedy(logits)))  # [slots, k]

        # 3) accept longest matching prefix; roll back caches per slot
        accepted = np.zeros(self.max_slots, np.int64)
        out = np.zeros((self.max_slots, k), np.int32)
        for s in range(self.max_slots):
            n = 0
            while n < k - 1 and window[s, n + 1] == target[s, n]:
                n += 1
            accepted[s] = n + 1                            # +1: free token
            out[s, : n + 1] = target[s, : n + 1]
        # target cache advanced by k for every slot; rewind to accepted
        rewind = jnp.asarray(k - accepted, jnp.int32)
        self.cache["pos"] = self.cache["pos"] - rewind
        # resync draft cache to the target position
        if self.draft_cache is not None:
            self.draft_cache["pos"] = jnp.minimum(
                self.draft_cache["pos"], self.cache["pos"]
            )
        return out, accepted.astype(np.float64), None

    def step(self) -> None:
        if self._sanitizer is None:
            return self._step_impl()
        stats0 = len(self.stats)
        with self._sanitizer.scope(self):
            self._step_impl()
        self._sanitizer.after_step(self, stepped=len(self.stats) > stats0)

    def sanitize_report(self):
        """The sanitizer's accumulated budget/compile counters, or None
        when the engine was built without ``sanitize=True``."""
        return None if self._sanitizer is None else self._sanitizer.report

    def _step_impl(self) -> None:
        t0 = time.perf_counter()
        transfers0 = self.host_transfers
        results0 = len(self.results)
        preempted0 = self.preemptions
        self._degraded_this_step = False
        if self.tracer.enabled:
            # events emitted anywhere below (including by the page manager,
            # which doesn't know the iteration) default to this step index
            self.tracer.iteration = self.iteration
        if self.faults is not None and self.faults.crash_now(self.iteration):
            # simulated process death: no cleanup, no emission, no journal
            # finalization — exactly what recovery must cope with
            if self.tracer.enabled:
                self.tracer.emit("fault", self.iteration, fault="crash")
            raise EngineCrashError(
                f"injected crash at iteration {self.iteration}",
                self.iteration)
        if self.faults is not None:
            delay = self.faults.step_delay(self.iteration)
            if delay > 0:
                if self.tracer.enabled:
                    self.tracer.emit("fault", self.iteration,
                                     fault="latency", delay_s=delay)
                time.sleep(delay)
        self._expire_deadlines()
        admitted = self._admit()
        # deferral-age accounting: consecutive iterations the SAME queue
        # head has been deferred by the pool (slot-limited waits don't
        # count — only can_admit failures / injected admission faults set
        # `_deferred_head`)
        if self._deferred_head is None:
            self._defer_age = 0
            self._defer_head = None
        elif self._deferred_head != self._defer_head:
            self._defer_head = self._deferred_head
            self._defer_age = 1
        else:
            self._defer_age += 1
        if self._deferred_head is not None:
            if self.tracer.enabled:
                self.tracer.emit("defer", self.iteration,
                                 req_id=self._deferred_head,
                                 age=self._defer_age)
            log.debug("queue head %d deferred by the pool (age %d)",
                      self._deferred_head, self._defer_age)
        if self._defer_age and self._should_preempt() and self._preempt_one():
            # pages freed — retry admission immediately so the head's
            # admission delay is bounded by K, not K + another deferral
            admitted += self._admit()
            if self._deferred_head is None:
                self._defer_age = 0
        arrived = self._arrived_this_step
        self._arrived_this_step = 0
        active = self.active_slots
        if not active:
            # Still a step: count it, or `run(max_iterations=)` is a dead
            # guard — paged admission deferring with nothing active would
            # spin this loop forever (regression-tested).
            self.scheduler.observe_counts(0, admitted)
            if self.tracer.enabled:
                self._trace_scheduler()
            self.iteration += 1
            self._watchdog(admitted > 0 or len(self.results) > results0
                           or self.preemptions > preempted0)
            self._check_invariants()
            if self.tracer.enabled:
                self.tracer.span(
                    "iteration", t0,
                    fc_variant=self.scheduler.fc_assignment,
                    rlp=self.scheduler.rlp, tlp=self.scheduler.tlp,
                    ai_estimate=self.scheduler.ai_estimate, new_tokens=0,
                    degraded=0, decode_slots=0, prefill_slots=0,
                    queued=len(self.queue), arrivals=arrived,
                    transfers=self.host_transfers - transfers0, idle=True)
            return

        speculating = self.spec_len > 1 and self.draft_cfg is not None
        prefilling = self._prefilling_slots() if self.stream_chunks else []
        chunked = len(prefilling)
        if prefilling and not speculating:
            # TLP=1 continuous batching: decodes + prefill chunks in ONE
            # fused program (the wave handles its own page mapping)
            decoding = [s for s in active if s not in set(prefilling)]
            out, accepted, _fin = self._mixed_wave_iteration(prefilling,
                                                             decoding)
        else:
            if prefilling:
                # speculative serve: advance the prefill frontier first so a
                # slot finishing its prompt this iteration rides the verify
                # program below, exactly like offline admission
                self._chunk_wave(prefilling)
            pset = set(prefilling)
            decoding = [s for s in self.active_slots
                        if s not in pset
                        or int(self.slot_offset[s])
                        >= int(self.slot_prompt[s])]
            out = np.zeros((self.max_slots, 1), np.int32)
            accepted = np.zeros(self.max_slots)
            if decoding:
                if self.kv is not None:
                    # map pages for the KV this iteration writes (positions
                    # pos..pos+tlp-1).  Cannot fail: the admission
                    # reservation covers prompt + max_new + window, and
                    # coverage never exceeds it before the request finishes.
                    tlp = self.spec_len if speculating else 1
                    for s in decoding:
                        self.kv.ensure(s, self._slot_pos(s) + tlp)
                    self._sync_tables()

                # the eos flags in the bundle are a device-side convenience
                # for callers (launch.serve); the host loop below re-derives
                # finishes anyway since length-based finishes need
                # per-request budgets
                out, accepted, _fin = self._decode_all()

        # host-side bookkeeping: append tokens, detect eos / length
        iter_tokens: list[int] = []
        finished_flags = np.zeros(self.max_slots, bool)
        for s in decoding:
            req = self.slot_req[s]
            if req is None:      # instant-finished by this iteration's wave
                continue
            n_acc = int(accepted[s])
            for j in range(n_acc):
                tok = int(out[s, j])
                self.slot_tokens[s].append(tok)
                iter_tokens.append(tok)
                if tok == self.eos_token or (
                    len(self.slot_tokens[s]) >= self.slot_budget[s]
                ):
                    reason = "eos" if tok == self.eos_token else "length"
                    self._emit(req, self.slot_tokens[s], reason, slot=s)
                    self.slot_req[s] = None
                    finished_flags[s] = True
                    break
            else:
                self.slot_last[s] = self.slot_tokens[s][-1]
                if self.kv is not None and speculating and (
                        n_acc < self.spec_len):
                    # speculative rollback returned the cache position to
                    # the accepted prefix; pages past it hold only the
                    # rejected window tail — return them to the pool (the
                    # admission reservation keeps them claimable, so next
                    # iteration's ensure() re-maps without risk)
                    self.kv.rewind(s, self._slot_pos(s))
                continue
            # slot freed: park its position on a safe nonzero value
            self.slot_last[s] = 0
            if self.kv is not None:
                self.kv.release(s)

        if self.journal is not None:
            self._journal_commits()

        # park inactive slots at pos=1 so their garbage decode can't creep
        # past the cache capacity (they are masked from outputs anyway).
        # Fixed-shape [max_slots] mask: the same compiled where() serves any
        # inactive set, unlike a dynamic gather index which retraces per set.
        inactive = np.array([r is None for r in self.slot_req])
        if inactive.any():
            mask = jnp.asarray(inactive)
            one = jnp.ones((), jnp.int32)
            self.cache["pos"] = jnp.where(mask, one, self.cache["pos"])
            if self.draft_cache is not None:
                self.draft_cache["pos"] = jnp.where(
                    mask, one, self.draft_cache["pos"])

        # 4) the PAPI runtime scheduling step (§5.2.2): the per-slot finished
        # flags go to the scheduler as an array — it sums them itself.
        self.scheduler.observe_counts(finished_flags, admitted)
        if self.tracer.enabled:
            self._trace_scheduler()
        self.iteration += 1
        self._watchdog(admitted > 0 or len(iter_tokens) > 0 or chunked > 0
                       or len(self.results) > results0
                       or self.preemptions > preempted0)
        self._check_invariants()
        kv_used = kv_free = kv_peak = 0
        kv_frag = 0.0
        if self.kv is not None:
            live_tokens = sum(self._tokens_written(s)
                              for s in range(self.max_slots)
                              if self.slot_req[s] is not None)
            ps = self.kv.stats(live_tokens)
            kv_used, kv_free = ps.mapped, ps.free
            kv_peak, kv_frag = ps.watermark, ps.fragmentation
        self.stats.append(IterStats(
            preemptions=self.preemptions - preempted0,
            deferral_age=self._defer_age,
            degraded=1 if self._degraded_this_step else 0,
            iteration=self.iteration,
            rlp=self.scheduler.rlp,
            tlp=self.scheduler.tlp,
            ai_estimate=self.scheduler.ai_estimate,
            fc_variant=self.scheduler.fc_assignment,
            new_tokens=len(iter_tokens),
            accepted=(float(np.mean(accepted[decoding]))
                      if len(decoding) else 0.0),
            wall_s=time.perf_counter() - t0,
            transfers=self.host_transfers - transfers0,
            kv_pages_used=kv_used,
            kv_pages_free=kv_free,
            kv_page_watermark=kv_peak,
            kv_fragmentation=kv_frag,
            arrivals=arrived,
            admitted=admitted,
            queued=len(self.queue),
            prefill_slots=chunked,
            decode_slots=len(decoding),
        ))
        if self.tracer.enabled:
            if self.kv is not None:
                self.tracer.emit("pool", used=kv_used, free=kv_free,
                                 watermark=kv_peak, fragmentation=kv_frag)
            st = self.stats[-1]
            self.tracer.span(
                "iteration", t0, fc_variant=st.fc_variant, rlp=st.rlp,
                tlp=st.tlp, ai_estimate=st.ai_estimate,
                new_tokens=st.new_tokens, degraded=st.degraded,
                decode_slots=st.decode_slots,
                prefill_slots=st.prefill_slots, queued=st.queued,
                arrivals=st.arrivals, transfers=st.transfers)

    def _trace_scheduler(self) -> None:
        """Emit this iteration's scheduling decision with its INPUTS (the
        AI estimate and the alpha threshold it was compared against), not
        just the chosen variant — the flip timeline in a trace must show
        why each decision went the way it did."""
        ev = self.scheduler.events[-1]
        self.tracer.emit("scheduler", self.iteration,
                         ai_estimate=ev.ai_estimate, alpha=ev.alpha,
                         assignment=ev.assignment, flipped=ev.rescheduled,
                         rlp=ev.rlp, tlp=ev.tlp)

    def set_spec_len(self, tlp: int) -> None:
        """Host updates the TLP register (dynamic speculation length).

        Both layouts budget admission for `prompt + max_new + window`, so
        widening the window mid-flight must re-check every LIVE slot or the
        verify step's KV writes overrun what admission reserved:

        * paged — the admission reservation covered the OLD window's pages;
          widening re-budgets live slots' reservations and clamps the
          window to what the free pool (and block-table width) can cover,
          or the per-iteration `ensure()` could exhaust the pool mid-flight;
        * dense — a live slot's slab holds `prompt + budget + OLD window`
          tokens; a wider window would make the verify step's
          dynamic_update_slice run past `cache_capacity`, where it CLAMPS
          downward and silently corrupts earlier live KV.  The window is
          clamped to the smallest live slot's headroom instead.

        Narrower is always affordable; on clamp the scheduler simply gets a
        smaller TLP than it asked for this cycle.
        """
        if tlp != self.spec_len:
            tlp = (self._rebudget_spec_window(tlp) if self.kv is not None
                   else self._clamp_spec_window_dense(tlp))
        self.spec_len = tlp
        self.scheduler.set_tlp(tlp)

    def _clamp_spec_window_dense(self, tlp: int) -> int:
        """Dense layout: admission guaranteed `prompt + budget + old_window
        <= cache_capacity` per live slot, so the widest window every live
        slot can hold is its remaining slab headroom."""
        want = max(tlp, 1)
        live = [s for s in range(self.max_slots)
                if self.slot_req[s] is not None]
        for s in live:
            headroom = (self.capacity - int(self.slot_prompt[s])
                        - int(self.slot_budget[s]))
            want = min(want, max(headroom, 1))
        return want if want != max(tlp, 1) else tlp

    def _rebudget_spec_window(self, tlp: int) -> int:
        """Adjust live slots' page reservations from the current speculative
        window to `tlp`'s; returns the (possibly clamped) window every live
        slot can actually hold — bounded by BOTH the free pool and the
        block-table width (a slot admitted near `max_blocks * page_size`
        tokens has no table rows left for a wider window)."""
        old_win = max(self.spec_len, 1)
        live = [s for s in range(self.max_slots)
                if self.slot_req[s] is not None]

        def budget(s: int, win: int) -> int:
            base = int(self.slot_prompt[s]) + int(self.slot_budget[s])
            return self.kv.pages_for(base + win)

        def delta(s: int, new_win: int) -> int:
            return budget(s, new_win) - budget(s, old_win)

        want = max(tlp, 1)
        while want > old_win and (
                sum(delta(s, want) for s in live) > self.kv.alloc.available
                or any(budget(s, want) > self.kv.max_blocks for s in live)):
            want -= 1
        for s in live:
            self.kv.alloc.reserve_more(s, delta(s, want))
        return want if want != max(tlp, 1) else tlp
