"""Logical-axis sharding: flax-style axis rules without flax.

Model code annotates activations/params with *logical* axis names
("batch", "seq", "heads", "ffn", "experts", "kv_seq", ...).  A context-local
rule table maps logical names to mesh axis names (or None).  Outside any
`axis_rules(...)` context (e.g. single-device CPU tests) every annotation is
the identity, so the same model code runs unsharded.

Mesh axes (production): ("pod", "data", "model") or ("data", "model").
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _current_rules() -> Mapping[str, object] | None:
    return getattr(_state, "rules", None)


def _current_mesh():
    return getattr(_state, "mesh", None)


def current_rules() -> Mapping[str, object] | None:
    """The installed logical->mesh rule table, or None outside axis_rules."""
    return _current_rules()


def current_mesh():
    """The installed mesh, or None outside axis_rules (single-device)."""
    return _current_mesh()


def fc_tensor_axis(bank: str = "ffn") -> tuple[object, str | None]:
    """(mesh, axis) for an FC weight's tensor-parallel split — the mesh axis
    the rule table maps the weight's *bank* logical dim onto (PAPI §5.3: one
    FC-PIM bank per shard of that axis; the bank dim is "ffn" for MLP
    weights, "heads"/"kv_heads" for attention projections).  Returns
    (None, None) outside a mesh context and (mesh, None) when the rules
    replicate that dim or the axis is trivial, so callers fall back to the
    unsharded kernel — keeping the kernel's split in lockstep with how the
    weight is actually stored."""
    mesh, rules = _current_mesh(), _current_rules()
    if mesh is None or rules is None:
        return None, None
    axis = rules.get(bank)
    if not isinstance(axis, str) or axis not in dict(mesh.shape) \
            or mesh.shape[axis] <= 1:
        return mesh, None
    return mesh, axis


@contextlib.contextmanager
def axis_rules(rules: Mapping[str, object], mesh=None):
    """Install logical->mesh axis rules.  Values are mesh axis names, tuples
    of mesh axis names, or None."""
    prev_rules = _current_rules()
    prev_mesh = _current_mesh()
    _state.rules = dict(rules)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev_rules
        _state.mesh = prev_mesh


def logical_to_spec(logical: Sequence[str | None]) -> P:
    """Resolve a tuple of logical axis names to a PartitionSpec."""
    rules = _current_rules()
    if rules is None:
        return P(*([None] * len(logical)))
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        else:
            out.append(rules.get(name))
    return P(*out)


def _axis_prod(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def filter_spec_for_shape(spec: P, shape: Sequence[int], mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim,
    and de-duplicate mesh axes (first dim wins).

    This lets one rule table serve every architecture: e.g. `heads -> model`
    applies to command-r (96 % 16 == 0) but silently replicates for
    qwen2-0.5b (14 heads); and a tensor whose dims map two logical names to
    the same mesh axis (logits under sequence parallelism: seq AND vocab ->
    model) keeps only the first.
    """
    used: set = set()
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None or mesh is None:
            out.append(entry)
            continue
        atoms = tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)
        if any(a in used for a in atoms) or dim % _axis_prod(mesh, entry) != 0:
            out.append(None)
        else:
            out.append(entry)
            used.update(atoms)
    return P(*out)


def shard(x, *logical: str | None):
    """Annotate `x` with a sharding constraint derived from logical axes.

    No-op when no rules are installed (CPU unit tests) or when the resolved
    spec is fully replicated.  Dims not divisible by the mapped mesh axes are
    replicated instead (arch-dependent head counts etc.).
    """
    rules = _current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(logical)
    mesh = _current_mesh()
    if mesh is not None:
        spec = filter_spec_for_shape(spec, x.shape, mesh)
    if all(s is None for s in spec):
        return x
    if mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec)
        )
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Rule tables.  `fsdp` shards the non-TP dim of big weights over the data axis
# (ZeRO-3 style gather-per-layer); disable for inference-only lowerings.
# ---------------------------------------------------------------------------

def tree_shardings(axes_tree, shapes_tree, rules: Mapping[str, object], mesh):
    """Resolve a pytree of logical-axis tuples into NamedShardings, dropping
    any axis whose mesh product does not divide the dim (per-arch head
    counts, ragged vocabs, ...)."""
    def one(axes, sds):
        with axis_rules(rules, mesh):
            spec = logical_to_spec(tuple(axes))
        spec = filter_spec_for_shape(spec, sds.shape, mesh)
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree.map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda x: (
            isinstance(x, tuple)
            and not hasattr(x, "_fields")      # NamedTuples are containers
            and all(isinstance(e, (str, type(None), tuple)) for e in x)
        ),
    )


def train_rules(multi_pod: bool = False, fsdp: bool = True) -> dict:
    data = ("pod", "data") if multi_pod else "data"
    rules = {
        # activations
        "batch": data,
        "seq": "model",          # sequence parallelism on the residual stream
        "act_heads": "model",
        "act_kv_heads": "model",
        # FFN intermediates inherit (batch, seq) sharding instead of a
        # forced f-dim constraint: keeping BOTH dW operands seq-aligned lets
        # the partitioner emit partial-dW + reduce-scatter (weight-sized)
        # instead of all-gathering the f32 activations over batch AND seq
        # (4.3GB/layer for granite-8b) — §Perf iteration 3.
        "act_ffn": None,
        "act_experts": "model",
        "act_kv_seq": None,      # train: KV not cached
        "vocab": "model",
        # params
        "heads": "model",
        "kv_heads": None,        # kv heads < 16 everywhere; replicate
        "ffn": "model",
        "experts": "model",
        "embed_vocab": "model",
        "ssm_heads": "model",
        "d_model": None,
        "fsdp": data if fsdp else None,   # second dim of big weights
        "scan": None,
    }
    return rules


def serve_rules(multi_pod: bool = False, long_context: bool = False,
                attn_pim: bool = False) -> dict:
    """Inference rules.  Decode shards the KV cache sequence dim over `model`
    (context parallelism — the Attn-PIM disaggregation analogue); for
    long-context batch=1 the cache seq dim spans (data, model) and activations
    replicate over data.

    ``attn_pim=True`` moves the KV split from the sequence dim to the KV
    *head* dim (overriding ``long_context``): the flash-decode Pallas kernel
    is shard_mapped one Attn-PIM unit per KV-head shard, so the cache must be
    *stored* head-sharded or every decode step would reshard it seq->head and
    back.  Head counts that don't divide the axis replicate — which again
    matches the kernel's replicated fallback."""
    data = ("pod", "data") if multi_pod else "data"
    kv_seq = ("data", "model") if long_context else "model"
    if multi_pod and long_context:
        kv_seq = ("pod", "data", "model")
    rules = {
        "batch": None if long_context else data,
        "seq": None,             # decode q_len is tiny; prefill chunks handle seq
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_ffn": "model",
        "act_experts": "model",
        "act_kv_seq": kv_seq,
        "vocab": "model",
        "heads": "model",
        "kv_heads": None,
        "ffn": "model",
        "experts": "model",
        "embed_vocab": "model",
        "ssm_heads": "model",
        "d_model": None,
        "fsdp": None,            # inference: weights fully resident
        "scan": None,
    }
    if attn_pim:
        rules["act_kv_seq"] = None
        rules["kv_heads"] = "model"
    return rules
