"""The FC execution-path hook — where PAPI's scheduling decision lands.

Model code routes every FC projection (QKV, out-proj, FFN — the paper's "FC
kernels") through `papi_linear`.  A context-local variant selects the
execution path:

  "pu"  (default) — XLA dot_general onto the MXU: the compute-bound path.
  "pim"           — the weight-streaming `fc_gemv` Pallas kernel: the
                    memory-bound path (FC-PIM analogue).

The serving engine sets the variant per decode iteration from
`core.scheduler.PapiScheduler`; both paths are numerically interchangeable
(tested) so flipping is free.  Outside a `fc_variant(...)` context the hook
is the plain einsum — training and the dry-run lower the XLA path.

Mesh execution (§5.3: FC-PIM banks)
-----------------------------------
Under `distributed.sharding.axis_rules(serve_rules(), mesh)` the two paths
split the FC weight over the tensor axis (the mesh axis the rules map the
logical "ffn" dim onto):

  * "pu" stays a plain einsum — GSPMD partitions it from the weight/activation
    sharding constraints;
  * "pim" cannot be auto-partitioned (a Pallas kernel is opaque to GSPMD), so
    it is wrapped in `shard_map`: each mesh shard streams its *local* weight
    bank through `fc_gemv`, which is exactly the paper's one-FC-PIM-bank-per-
    channel layout.  Column-split weights (`tp="col"`: QKV, gate/up) shard the
    output dim — no collective; row-split weights (`tp="row"`: out-proj, down)
    shard the contraction dim and `psum` the partial products, the analogue of
    the PIM channels' reduction tree.

Call sites declare which dim carries the tensor split via ``tp``, plus the
*logical* bank dim (``bank``: "ffn" for MLP weights, "heads"/"kv_heads" for
attention projections) and its unit count (``units``: head count for the
flattened QKV/out weights).  The split only engages when the rule table
actually maps that logical dim onto a mesh axis AND the unit count divides
it — the exact conditions under which `filter_spec_for_shape` shards the
stored weight — so the kernel's bank layout always matches the weight's
resident sharding and no per-call resharding is provoked.  Everything else
falls back to the replicated kernel.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import fc_tensor_axis

_state = threading.local()


def current_fc_variant() -> str:
    return getattr(_state, "variant", "pu")


def current_fc_interpret() -> bool | None:
    return getattr(_state, "interpret", None)


@contextlib.contextmanager
def fc_variant(variant: str, interpret: bool | None = None):
    assert variant in ("pu", "pim"), variant
    prev = current_fc_variant()
    prev_i = getattr(_state, "interpret", None)
    _state.variant = variant
    _state.interpret = interpret
    try:
        yield
    finally:
        _state.variant = prev
        _state.interpret = prev_i


def _pim_gemv(x2: jax.Array, w: jax.Array, tp: str | None, bank: str,
              units: int | None) -> jax.Array:
    """[m, K] @ [K, N] through fc_gemv, sharded one bank per mesh shard."""
    from repro.kernels.fc_gemv import fc_gemv

    interpret = getattr(_state, "interpret", None)
    mesh, axis = fc_tensor_axis(bank)
    k, n = w.shape
    if units is None:
        units = n if tp == "col" else k
    if mesh is not None and axis is not None and units % mesh.shape[axis] == 0:
        size = mesh.shape[axis]
        if tp == "col" and n % size == 0:
            # output-dim banks: every shard produces its own slice, no
            # collective (QKV / gate / up projections)
            return shard_map(
                lambda xs, ws: fc_gemv(xs, ws, interpret=interpret),
                mesh=mesh, in_specs=(P(), P(None, axis)),
                out_specs=P(None, axis), check_rep=False,
            )(x2, w)
        if tp == "row" and k % size == 0:
            # contraction-dim banks: shards hold partial products, reduced
            # over the tensor axis (out-proj / down projections)
            def _row(xs, ws):
                return jax.lax.psum(fc_gemv(xs, ws, interpret=interpret),
                                    axis)
            return shard_map(
                _row, mesh=mesh, in_specs=(P(None, axis), P(axis, None)),
                out_specs=P(), check_rep=False,
            )(x2, w)
    return fc_gemv(x2, w, interpret=interpret)


def papi_linear(x: jax.Array, w: jax.Array, *, tp: str | None = None,
                bank: str = "ffn", units: int | None = None) -> jax.Array:
    """x: [..., K] @ w: [K, N] through the scheduled FC path.

    ``tp`` declares which weight dim carries the tensor-parallel split under
    a mesh: "col" (N is the sharded bank dim), "row" (K is; partials are
    psum-reduced), or None (always replicated); ``bank``/``units`` name the
    logical dim behind that split and its unit count so the split engages
    exactly when the stored weight is sharded (module docstring).  All
    ignored outside a mesh context.  Block sizes are left to `fc_gemv`'s
    auto-tuner, which sizes the tiles to the double-buffered VMEM budget
    instead of a fixed 512."""
    if current_fc_variant() == "pim":
        lead = x.shape[:-1]
        k, n = w.shape
        m = 1
        for d in lead:
            m *= d
        out = _pim_gemv(x.reshape(m, k), w, tp, bank, units)
        return out.reshape(*lead, n)
    return jnp.einsum("...k,kn->...n", x, w)
