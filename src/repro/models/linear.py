"""The FC execution-path hook — where PAPI's scheduling decision lands.

Model code routes every FC projection (QKV, out-proj, FFN — the paper's "FC
kernels") through `papi_linear`.  A context-local variant selects the
execution path:

  "pu"  (default) — XLA dot_general onto the MXU: the compute-bound path.
  "pim"           — the weight-streaming `fc_gemv` Pallas kernel: the
                    memory-bound path (FC-PIM analogue).

The serving engine sets the variant per decode iteration from
`core.scheduler.PapiScheduler`; both paths are numerically interchangeable
(tested) so flipping is free.  Outside a `fc_variant(...)` context the hook
is the plain einsum — training and the dry-run lower the XLA path.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

_state = threading.local()


def current_fc_variant() -> str:
    return getattr(_state, "variant", "pu")


def current_fc_interpret() -> bool | None:
    return getattr(_state, "interpret", None)


@contextlib.contextmanager
def fc_variant(variant: str, interpret: bool | None = None):
    assert variant in ("pu", "pim"), variant
    prev = current_fc_variant()
    prev_i = getattr(_state, "interpret", None)
    _state.variant = variant
    _state.interpret = interpret
    try:
        yield
    finally:
        _state.variant = prev
        _state.interpret = prev_i


def papi_linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [..., K] @ w: [K, N] through the scheduled FC path.

    Block sizes are left to `fc_gemv`'s auto-tuner, which sizes the tiles to
    the double-buffered VMEM budget instead of a fixed 512."""
    if current_fc_variant() == "pim":
        from repro.kernels.fc_gemv import fc_gemv
        lead = x.shape[:-1]
        k, n = w.shape
        m = 1
        for d in lead:
            m *= d
        out = fc_gemv(
            x.reshape(m, k), w,
            interpret=getattr(_state, "interpret", None),
        )
        return out.reshape(*lead, n)
    return jnp.einsum("...k,kn->...n", x, w)
