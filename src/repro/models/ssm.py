"""Mamba2 / SSD (state-space duality) block — pure-JAX chunked algorithm.

Recurrence (per head h, head_dim p, state n):
    S_t = exp(dt_t * A) * S_{t-1} + dt_t * (x_t outer B_t)      S: [p, n]
    y_t = S_t @ C_t + D * x_t

Training/prefill use the chunked SSD form (Mamba2 paper §6): intra-chunk
contributions are dense matmuls (MXU-friendly), inter-chunk states compose
through a log-depth associative scan.  Decode uses the O(1) recurrent step.

TP: heads (and the head-major d_inner dim) shard over the `model` mesh axis;
B/C are group-shared (n_groups=1) and replicate.
"""
from __future__ import annotations

from typing import Mapping, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.distributed.sharding import shard

Params = Mapping[str, jax.Array]


class SSMState(NamedTuple):
    conv_x: jax.Array   # [b, K-1, di]
    conv_B: jax.Array   # [b, K-1, n]
    conv_C: jax.Array   # [b, K-1, n]
    ssm: jax.Array      # [b, nh, hp, n] (f32)


def init_state(batch: int, d_model: int, s: SSMConfig, dtype) -> SSMState:
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    k = s.conv_kernel - 1
    return SSMState(
        conv_x=jnp.zeros((batch, k, di), dtype),
        conv_B=jnp.zeros((batch, k, s.d_state), dtype),
        conv_C=jnp.zeros((batch, k, s.d_state), dtype),
        ssm=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    )


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None):
    """Depthwise causal conv1d.  x: [b, l, c]; w: [K, c].

    Returns (y [b, l, c], new_state [b, K-1, c]).  `state` carries the last
    K-1 inputs from the previous call (decode); None => zero history (train).
    """
    k = w.shape[0]
    if state is None:
        hist = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)            # [b, l+K-1, c]
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :, :]
    return y, new_state


def _ssd_chunked(
    x: jax.Array,    # [b, l, nh, hp]
    dt: jax.Array,   # [b, l, nh] (post-softplus, f32)
    A: jax.Array,    # [nh] (negative, f32)
    B: jax.Array,    # [b, l, n]
    C: jax.Array,    # [b, l, n]
    chunk: int,
    init_state: jax.Array | None = None,   # [b, nh, hp, n] f32
):
    """Chunked SSD.  Returns (y [b, l, nh, hp], final_state [b, nh, hp, n])."""
    b, l, nh, hp = x.shape
    n = B.shape[-1]
    cs = min(chunk, l)
    assert l % cs == 0, f"seq {l} not divisible by chunk {cs}"
    nc = l // cs
    f32 = jnp.float32

    xc = x.reshape(b, nc, cs, nh, hp)
    dtc = dt.reshape(b, nc, cs, nh).astype(f32)
    Bc = B.reshape(b, nc, cs, n)
    Cc = C.reshape(b, nc, cs, n)

    lt = dtc * A[None, None, None, :]                     # log-decay per step
    cum = jnp.cumsum(lt, axis=2)                          # [b, nc, cs, nh]

    # --- intra-chunk (dense, MXU-friendly) --------------------------------
    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(f32), Bc.astype(f32))
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [b, nc, i, j, nh]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    dtx = (dtc[..., None] * xc.astype(f32))               # [b, nc, cs, nh, hp]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", CB, L, dtx)

    # --- chunk summary states ---------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # [b, nc, cs, nh]
    S_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end, Bc.astype(f32), dtx)
    G_chunk = jnp.exp(cum[:, :, -1, :])                   # [b, nc, nh]

    # --- inter-chunk recurrence: associative scan over transforms ----------
    #   state_after_c = G_c * state_before_c + S_c
    def combine(a, bb):
        g1, s1 = a
        g2, s2 = bb
        return g1 * g2, g2[..., None, None] * s1 + s2

    G_in, S_in = G_chunk, S_chunk
    if init_state is not None:
        # Prepend the incoming state as a pseudo-chunk with unit decay.
        G_in = jnp.concatenate([jnp.ones((b, 1, nh), f32), G_chunk], axis=1)
        S_in = jnp.concatenate([init_state[:, None].astype(f32), S_chunk], axis=1)
    G_acc, S_acc = jax.lax.associative_scan(combine, (G_in, S_in), axis=1)
    if init_state is not None:
        S_before = S_acc[:, :-1]                          # state entering chunk c
        final_state = S_acc[:, -1]
    else:
        S_before = jnp.concatenate(
            [jnp.zeros((b, 1, nh, hp, n), f32), S_acc[:, :-1]], axis=1
        )
        final_state = S_acc[:, -1]

    # --- inter-chunk contribution ------------------------------------------
    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp", Cc.astype(f32), jnp.exp(cum), S_before
    )
    y = (y_intra + y_inter).reshape(b, l, nh, hp)
    return y.astype(x.dtype), final_state


def _ssd_recurrent(
    x: jax.Array,    # [b, t, nh, hp]  (t small: decode / speculative verify)
    dt: jax.Array,   # [b, t, nh] f32
    A: jax.Array,    # [nh] f32
    B: jax.Array,    # [b, t, n]
    C: jax.Array,    # [b, t, n]
    state: jax.Array,  # [b, nh, hp, n] f32
):
    f32 = jnp.float32

    def step(s, inp):
        xt, dtt, Bt, Ct = inp                             # [b,nh,hp],[b,nh],[b,n],[b,n]
        g = jnp.exp(dtt * A[None, :])                     # [b, nh]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt.astype(f32), Bt.astype(f32))
        s = g[..., None, None] * s + upd
        y = jnp.einsum("bhpn,bn->bhp", s, Ct.astype(f32))
        return s, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0).astype(f32),
        jnp.moveaxis(B, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def mamba2_block(
    u: jax.Array,              # [b, l, d] (already normed)
    p: Params,
    s: SSMConfig,
    d_model: int,
    state: SSMState | None = None,
    decode: bool = False,
):
    """Full Mamba2 block.  Returns (out [b, l, d], new_state | None)."""
    b, l, d = u.shape
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    hp = s.head_dim

    z = jnp.einsum("bld,di->bli", u, p["w_z"])
    x = jnp.einsum("bld,di->bli", u, p["w_x"])
    Bp = jnp.einsum("bld,dn->bln", u, p["w_B"])
    Cp = jnp.einsum("bld,dn->bln", u, p["w_C"])
    dt = jnp.einsum("bld,dh->blh", u, p["w_dt"])
    x = shard(x, "batch", None, "ssm_heads")
    z = shard(z, "batch", None, "ssm_heads")

    cx, new_cx = _causal_conv(x, p["conv_x"], state.conv_x if state else None)
    cB, new_cB = _causal_conv(Bp, p["conv_B"], state.conv_B if state else None)
    cC, new_cC = _causal_conv(Cp, p["conv_C"], state.conv_C if state else None)
    cx = jax.nn.silu(cx.astype(jnp.float32)).astype(u.dtype)
    cB = jax.nn.silu(cB.astype(jnp.float32)).astype(u.dtype)
    cC = jax.nn.silu(cC.astype(jnp.float32)).astype(u.dtype)

    xh = cx.reshape(b, l, nh, hp)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        assert state is not None
        y, new_ssm = _ssd_recurrent(xh, dtf, A, cB, cC, state.ssm)
    else:
        init = state.ssm if state is not None else None
        y, new_ssm = _ssd_chunked(xh, dtf, A, cB, cC, s.chunk_size, init)

    y = y + p["D"].astype(u.dtype)[None, None, :, None] * xh
    y = y.reshape(b, l, di)

    # Gated RMSNorm: norm(y * silu(z)) * w  (mamba2's RMSNormGated)
    gated = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(gated), axis=-1, keepdims=True)
    gated = gated * jax.lax.rsqrt(var + 1e-5) * p["norm_w"].astype(jnp.float32)
    y = gated.astype(u.dtype)

    out = jnp.einsum("bli,id->bld", y, p["w_out"])
    new_state = SSMState(new_cx, new_cB, new_cC, new_ssm)
    return out, new_state
