"""Model definition: one parameterized decoder/encoder covering all assigned
families (dense / moe / ssm / hybrid / vlm / audio).

Parameters are described once by `model_spec(cfg)` (shape + logical sharding
axes + init law per leaf); `init_params` and `param_logical_axes` both derive
from it, so the two can never drift.  Layer parameters are stacked on a
leading `num_layers` axis and consumed by `jax.lax.scan` — this keeps the
lowered HLO size O(1) in depth (deepseek-67b has 95 layers) and is what the
multi-pod dry-run compiles.

Entry points:
  init_params(cfg, key)                 -> params pytree
  param_logical_axes(cfg)               -> matching pytree of logical axis tuples
  init_cache(cfg, batch, capacity)      -> decode cache pytree
  forward_train(cfg, params, batch)     -> (loss, metrics)
  prefill(cfg, params, batch, cache)    -> (last_logits, cache)
  prefill_chunk(cfg, params, cache, toks, lens) -> (next_tok, cache)
  decode_step(cfg, params, cache, toks) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

PyTree = Any


# ===========================================================================
# Parameter specs
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"      # normal | zeros | ones | a_log | dt_bias
    std: float = 0.02


def _attn_spec(cfg: ModelConfig, residual_std: float) -> dict[str, PSpec]:
    d, nh, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    std = d ** -0.5
    p = {
        "w_q": PSpec((d, nh, hd), ("fsdp", "heads", None), std=std),
        "w_k": PSpec((d, nkv, hd), ("fsdp", "kv_heads", None), std=std),
        "w_v": PSpec((d, nkv, hd), ("fsdp", "kv_heads", None), std=std),
        "w_o": PSpec((nh, hd, d), ("heads", None, "fsdp"), std=residual_std),
    }
    if cfg.qkv_bias:
        p["b_q"] = PSpec((nh, hd), ("heads", None), init="zeros")
        p["b_k"] = PSpec((nkv, hd), ("kv_heads", None), init="zeros")
        p["b_v"] = PSpec((nkv, hd), ("kv_heads", None), init="zeros")
    return p


def _mlp_spec(cfg: ModelConfig, residual_std: float) -> dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    std = d ** -0.5
    if cfg.mlp == "swiglu":
        return {
            "w_gate": PSpec((d, f), ("fsdp", "ffn"), std=std),
            "w_up": PSpec((d, f), ("fsdp", "ffn"), std=std),
            "w_down": PSpec((f, d), ("ffn", "fsdp"), std=residual_std),
        }
    return {
        "w_in": PSpec((d, f), ("fsdp", "ffn"), std=std),
        "b_in": PSpec((f,), ("ffn",), init="zeros"),
        "w_out": PSpec((f, d), ("ffn", "fsdp"), std=residual_std),
        "b_out": PSpec((d,), (None,), init="zeros"),
    }


def _moe_spec(cfg: ModelConfig, residual_std: float) -> dict[str, PSpec]:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.moe.d_ff, cfg.moe.num_experts
    std = d ** -0.5
    return {
        "w_router": PSpec((d, e), (None, None), std=std),
        "w_gate": PSpec((e, d, f), ("experts", "fsdp", None), std=std),
        "w_up": PSpec((e, d, f), ("experts", "fsdp", None), std=std),
        "w_down": PSpec((e, f, d), ("experts", None, "fsdp"), std=residual_std),
    }


def _ssm_spec(cfg: ModelConfig, residual_std: float) -> dict[str, PSpec]:
    assert cfg.ssm is not None
    s = cfg.ssm
    d = cfg.d_model
    di, nh, n, k = s.d_inner(d), s.n_heads(d), s.d_state, s.conv_kernel
    std = d ** -0.5
    return {
        "w_z": PSpec((d, di), ("fsdp", "ssm_heads"), std=std),
        "w_x": PSpec((d, di), ("fsdp", "ssm_heads"), std=std),
        "w_B": PSpec((d, n), ("fsdp", None), std=std),
        "w_C": PSpec((d, n), ("fsdp", None), std=std),
        "w_dt": PSpec((d, nh), ("fsdp", "ssm_heads"), std=std),
        "conv_x": PSpec((k, di), (None, "ssm_heads"), std=(1 / math.sqrt(k))),
        "conv_B": PSpec((k, n), (None, None), std=(1 / math.sqrt(k))),
        "conv_C": PSpec((k, n), (None, None), std=(1 / math.sqrt(k))),
        "A_log": PSpec((nh,), ("ssm_heads",), init="a_log"),
        "D": PSpec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": PSpec((nh,), ("ssm_heads",), init="dt_bias"),
        "norm_w": PSpec((di,), ("ssm_heads",), init="ones"),
        "w_out": PSpec((di, d), ("ssm_heads", "fsdp"), std=residual_std),
    }


def _layer_spec(cfg: ModelConfig, residual_std: float) -> dict[str, Any]:
    """Spec of ONE layer (unstacked)."""
    d = cfg.d_model
    if cfg.family in ("ssm", "hybrid"):
        return {
            "norm": PSpec((d,), (None,), init="ones"),
            "ssm": _ssm_spec(cfg, residual_std),
        }
    block = {
        "norm1": PSpec((d,), (None,), init="ones"),
        "attn": _attn_spec(cfg, residual_std),
        "norm2": PSpec((d,), (None,), init="ones"),
    }
    if cfg.family == "moe":
        block["moe"] = _moe_spec(cfg, residual_std)
    else:
        block["mlp"] = _mlp_spec(cfg, residual_std)
    return block


def model_spec(cfg: ModelConfig) -> dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    n_res = 2 * cfg.num_layers
    residual_std = (d ** -0.5) / math.sqrt(max(n_res, 1))

    spec: dict[str, Any] = {
        "embed": {"w": PSpec((v, d), ("embed_vocab", None), std=0.02)},
        "final_norm": {"w": PSpec((d,), (None,), init="ones")},
    }

    layer = _layer_spec(cfg, residual_std)
    spec["layers"] = jax.tree.map(
        lambda ps: PSpec(
            (cfg.num_layers,) + ps.shape, ("scan",) + ps.logical, ps.init, ps.std
        ),
        layer,
        is_leaf=lambda x: isinstance(x, PSpec),
    )

    if cfg.family == "hybrid":
        # One weight-tied attention+MLP block shared across applications.
        spec["shared"] = {
            "norm1": PSpec((d,), (None,), init="ones"),
            "attn": _attn_spec(cfg, residual_std),
            "norm2": PSpec((d,), (None,), init="ones"),
            "mlp": _mlp_spec(cfg, residual_std),
        }
    if cfg.family == "audio":
        spec["mask_embed"] = {"w": PSpec((d,), (None,), std=0.02)}
    if cfg.decoder and not cfg.tie_embeddings:
        spec["lm_head"] = {"w": PSpec((d, v), ("fsdp", "embed_vocab"), std=d ** -0.5)}
    return spec


def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    spec = model_spec(cfg)
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_pspec)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)

    def make(ps: PSpec, k: jax.Array) -> jax.Array:
        if ps.init == "zeros":
            return jnp.zeros(ps.shape, dtype)
        if ps.init == "ones":
            return jnp.ones(ps.shape, dtype)
        if ps.init == "a_log":
            assert cfg.ssm is not None
            u = jax.random.uniform(k, ps.shape, jnp.float32,
                                   cfg.ssm.a_min, cfg.ssm.a_max)
            return jnp.log(u)  # keep f32: A_log is a recurrence-critical param
        if ps.init == "dt_bias":
            # softplus^{-1}(dt) for dt ~ logU[1e-3, 1e-1]
            u = jax.random.uniform(k, ps.shape, jnp.float32)
            dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
            return dt + jnp.log(-jnp.expm1(-dt))
        x = jax.random.truncated_normal(k, -3.0, 3.0, ps.shape, jnp.float32)
        return (x * ps.std).astype(dtype)

    params = [make(ps, k) for ps, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, params)


def param_logical_axes(cfg: ModelConfig) -> PyTree:
    return jax.tree.map(lambda ps: ps.logical, model_spec(cfg), is_leaf=_is_pspec)


def param_shapes(cfg: ModelConfig) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)

    def to_sds(ps: PSpec):
        dt = jnp.float32 if ps.init in ("a_log", "dt_bias") else dtype
        return jax.ShapeDtypeStruct(ps.shape, dt)

    return jax.tree.map(to_sds, model_spec(cfg), is_leaf=_is_pspec)


def param_shardings(cfg: ModelConfig, rules, mesh) -> PyTree:
    """NamedShardings for the params pytree under a rule table + mesh.
    Dims the mesh axes don't divide replicate (per-arch head counts etc.) —
    the same `filter_spec_for_shape` policy activation constraints use."""
    from repro.distributed.sharding import tree_shardings
    return tree_shardings(param_logical_axes(cfg), param_shapes(cfg),
                          rules, mesh)


def cache_shardings(cfg: ModelConfig, batch: int, capacity: int,
                    rules, mesh) -> PyTree:
    """NamedShardings for the decode cache (mirrors init_cache).  Under
    `serve_rules()` the KV sequence dim lands on the tensor axis — each
    shard owns a contiguous KV slice, the Attn-PIM-next-to-its-KV layout."""
    from repro.distributed.sharding import tree_shardings
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, capacity))
    return tree_shardings(cache_logical_axes(cfg), shapes, rules, mesh)


# ===========================================================================
# KV / state caches
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> PyTree:
    """Decode cache.  `capacity` = max sequence length held."""
    dtype = jnp.dtype(cfg.dtype)
    cache: dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    nkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        cache["k"] = jnp.zeros((cfg.num_layers, batch, capacity, nkv, hd), dtype)
        cache["v"] = jnp.zeros((cfg.num_layers, batch, capacity, nkv, hd), dtype)
    elif cfg.family == "ssm":
        assert cfg.ssm is not None
        st = S.init_state(batch, cfg.d_model, cfg.ssm, dtype)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), st
        )
    elif cfg.family == "hybrid":
        assert cfg.ssm is not None and cfg.hybrid is not None
        st = S.init_state(batch, cfg.d_model, cfg.ssm, dtype)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), st
        )
        napps = cfg.num_attention_applications()
        cache["k"] = jnp.zeros((napps, batch, capacity, nkv, hd), dtype)
        cache["v"] = jnp.zeros((napps, batch, capacity, nkv, hd), dtype)
    return cache


def cache_logical_axes(cfg: ModelConfig) -> PyTree:
    """Logical axes for the cache pytree (mirrors init_cache)."""
    axes: dict[str, Any] = {"pos": (None,)}
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        axes["k"] = ("scan", "batch", "act_kv_seq", "kv_heads", None)
        axes["v"] = ("scan", "batch", "act_kv_seq", "kv_heads", None)
    if cfg.family in ("ssm", "hybrid"):
        axes["ssm"] = S.SSMState(
            conv_x=("scan", "batch", None, "ssm_heads"),
            conv_B=("scan", "batch", None, None),
            conv_C=("scan", "batch", None, None),
            ssm=("scan", "batch", "ssm_heads", None, None),
        )
    return axes


def init_paged_cache(cfg: ModelConfig, max_slots: int, num_pages: int,
                     page_size: int, max_blocks: int | None = None) -> PyTree:
    """Paged decode cache: KV lives in a pool of fixed-size pages (one page
    = one Attn-PIM bank row) instead of per-slot dense slabs, and a per-slot
    block table maps logical KV blocks to physical pages.

    Physical page 0 is the shared garbage page (never allocated — see
    `serving/kv_pages.py`): block tables init to 0, so writes from
    not-yet-admitted slots land there harmlessly.

    Total KV bytes scale with `num_pages * page_size` for the whole pool,
    not `max_slots * capacity` — and a single request may span (almost) the
    entire pool, which no dense slot layout permits.
    """
    assert cfg.family in ("dense", "moe", "vlm", "audio"), (
        f"paged KV cache needs a pure attention KV cache; {cfg.family} "
        "carries SSM state that has no sequence dim to page")
    if max_blocks is None:
        max_blocks = num_pages - 1
    dtype = jnp.dtype(cfg.dtype)
    nkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "pos": jnp.zeros((max_slots,), jnp.int32),
        "k": jnp.zeros((cfg.num_layers, num_pages, page_size, nkv, hd), dtype),
        "v": jnp.zeros((cfg.num_layers, num_pages, page_size, nkv, hd), dtype),
        "block_tables": jnp.zeros((max_slots, max_blocks), jnp.int32),
    }


def paged_cache_logical_axes(cfg: ModelConfig) -> PyTree:
    """Logical axes for the paged cache (mirrors init_paged_cache).  The
    page-pool dim replicates; the KV-head dim carries the Attn-PIM unit
    sharding (`serve_rules(attn_pim=True)` maps kv_heads -> model), so the
    head-sharded flash-decode layout from the dense cache carries over."""
    return {
        "pos": (None,),
        "k": ("scan", None, None, "kv_heads", None),
        "v": ("scan", None, None, "kv_heads", None),
        "block_tables": (None, None),
    }


def paged_cache_shardings(cfg: ModelConfig, max_slots: int, num_pages: int,
                          page_size: int, max_blocks: int | None,
                          rules, mesh) -> PyTree:
    """NamedShardings for the paged cache under a rule table + mesh."""
    from repro.distributed.sharding import tree_shardings
    shapes = jax.eval_shape(
        lambda: init_paged_cache(cfg, max_slots, num_pages, page_size,
                                 max_blocks))
    return tree_shardings(paged_cache_logical_axes(cfg), shapes, rules, mesh)


# ===========================================================================
# Blocks
# ===========================================================================

def _write_kv(k_cache, v_cache, k_new, v_new, pos):
    """Write [b, t, nkv, hd] at per-request positions pos [b]."""
    def upd(cache, new, p):
        return jax.lax.dynamic_update_slice(cache, new, (p, 0, 0))
    k_cache = jax.vmap(upd)(k_cache, k_new, pos)
    v_cache = jax.vmap(upd)(v_cache, v_new, pos)
    return k_cache, v_cache


def _write_kv_masked(k_cache, v_cache, k_new, v_new, pos, valid_lens):
    """Like `_write_kv`, but only the first `valid_lens[b]` of the t new
    tokens are written per request; the rest are dropped entirely.

    Chunked prefill needs this: the final chunk of a prompt is ragged, and
    slots that are not part of the chunk wave (live decoding requests, idle
    slots) ride the fixed-shape batch with valid_lens == 0.  A
    dynamic_update_slice cannot mask, and worse, it clamps a start index
    near the capacity edge DOWNWARD — silently overwriting earlier live KV.
    Scatter with out-of-range indices in "drop" mode does exactly what is
    needed: masked rows index one past the capacity and vanish.
    """
    b, t = k_new.shape[0], k_new.shape[1]
    cap = k_cache.shape[1]
    idx = pos[:, None] + jnp.arange(t)[None, :]               # [b, t]
    idx = jnp.where(jnp.arange(t)[None, :] < valid_lens[:, None], idx, cap)
    bidx = jnp.arange(b)[:, None]
    k_cache = k_cache.at[bidx, idx].set(k_new, mode="drop")
    v_cache = v_cache.at[bidx, idx].set(v_new, mode="drop")
    return k_cache, v_cache


def _paged_rows(pos, t, tables, page_size):
    """(physical page, row) coordinates for t new tokens per slot.

    Logical position `pos[b] + j` lands in logical block `(pos+j) //
    page_size` at row `(pos+j) % page_size`; the block table resolves the
    physical page.  Blocks past the table width clamp to the last entry —
    the engine guarantees mapped coverage for every *live* slot, and idle
    slots' tables are all garbage-page so their writes collide there
    harmlessly (see serving/kv_pages.py)."""
    tok = pos[:, None] + jnp.arange(t)[None, :]             # [b, t]
    blk = jnp.clip(tok // page_size, 0, tables.shape[1] - 1)
    phys = jnp.take_along_axis(tables, blk, axis=1)         # [b, t]
    return phys, tok % page_size


def _write_kv_paged(k_cache, v_cache, k_new, v_new, pos, tables,
                    valid_lens=None):
    """Scatter [b, t, nkv, hd] into the page pools [P, page, nkv, hd].

    With `valid_lens` (chunked prefill's ragged final chunk, and the
    valid_lens == 0 rows of slots that are not chunking this wave), tokens
    past the valid prefix are redirected to the shared garbage page 0 —
    they never touch a live request's pages."""
    page_size = k_cache.shape[1]
    phys, row = _paged_rows(pos, k_new.shape[1], tables, page_size)
    if valid_lens is not None:
        valid = jnp.arange(k_new.shape[1])[None, :] < valid_lens[:, None]
        phys = jnp.where(valid, phys, 0)
    k_cache = k_cache.at[phys, row].set(k_new)
    v_cache = v_cache.at[phys, row].set(v_new)
    return k_cache, v_cache


def _apply_positional(cfg: ModelConfig, q, k, positions):
    if cfg.family == "audio":
        return q, k  # hubert: conv positional frontend (stubbed) — no RoPE
    if cfg.m_rope:
        q = L.apply_m_rope(q, positions, cfg.rope_theta, tuple(cfg.m_rope_sections))
        k = L.apply_m_rope(k, positions, cfg.rope_theta, tuple(cfg.m_rope_sections))
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _decode_attention(q, k_cache, v_cache, pos, tables=None):
    """THE decision point for decode-path attention — plain decode,
    speculative verify windows, and chunked-prefill waves all land here
    with a [b, t, nh, hd] query window at consecutive absolute positions
    ``pos .. pos + t - 1`` (intra-window causal: KV position j is visible
    to window row r iff j <= pos + r).

    Under `attn_impl("pim")` EVERY case — any t >= 1, dense or paged — runs
    the Pallas flash-decode kernel (the Attn-PIM unit): dense streams the
    per-slot slab, paged resolves pages inside the kernel's block-table
    index_map, so `gather_kv_pages` never appears in a jitted program on
    this path.  The XLA softmax path (with the page gather when paged)
    remains as the tested oracle."""
    t = q.shape[1]
    if L.current_attn_impl() == "pim":
        if tables is not None:
            return L.decode_attention_pim_paged(q, k_cache, v_cache, tables,
                                                lens=pos + t)
        return L.decode_attention_pim(q, k_cache, v_cache, lens=pos + t)
    if tables is not None:
        # XLA oracle path: gather the slots' pages into a contiguous view
        # and reuse the dense ragged-masked attention
        k_cache = L.gather_kv_pages(k_cache, tables)
        v_cache = L.gather_kv_pages(v_cache, tables)
    return L.decode_attention_xla(q, k_cache, v_cache,
                                  cache_len=pos + t, q_offset=pos)


def attention_block(
    cfg: ModelConfig,
    p: Mapping[str, Any],
    h: jax.Array,
    positions: jax.Array,
    kv: tuple[jax.Array, jax.Array] | None,
    pos: jax.Array | None,
    mode: str,                      # train | prefill | decode
    tables: jax.Array | None = None,   # [b, max_blocks] => paged KV layout
    write_lens: jax.Array | None = None,  # [b] chunked prefill: valid new
                                          # tokens per slot (None = all t)
):
    """Pre-norm attention sub-block.  Returns (h, new_kv|None)."""
    a_in = L.norm(h, p["norm1"], cfg.norm, cfg.norm_eps)
    q, k, v = L.qkv_project(a_in, p["attn"], cfg.num_heads, cfg.num_kv_heads,
                            cfg.resolved_head_dim)
    q, k = _apply_positional(cfg, q, k, positions)
    new_kv = None
    if mode == "decode" and tables is not None:
        # paged layout: kv are page pools [num_pages, page, nkv, hd]
        assert kv is not None and pos is not None
        k_cache, v_cache = _write_kv_paged(kv[0], kv[1], k, v, pos, tables,
                                           valid_lens=write_lens)
        attn = _decode_attention(q, k_cache, v_cache, pos, tables)
        new_kv = (k_cache, v_cache)
    elif mode == "decode":
        assert kv is not None and pos is not None
        if write_lens is not None:
            # chunked prefill: ragged tails / non-chunking slots must not
            # write — and the hot decode path keeps its dynamic_update_slice
            k_cache, v_cache = _write_kv_masked(kv[0], kv[1], k, v, pos,
                                                write_lens)
        else:
            k_cache, v_cache = _write_kv(kv[0], kv[1], k, v, pos)
        attn = _decode_attention(q, k_cache, v_cache, pos)
        new_kv = (k_cache, v_cache)
    else:
        attn = L.flash_attention(q, k, v, causal=cfg.causal)
        if kv is not None:  # prefill: persist the new KV
            new_kv = _write_kv(kv[0], kv[1], k, v, jnp.zeros_like(pos))
    h = h + L.out_project(attn, p["attn"])
    h = shard(h, "batch", "seq", None)
    return h, new_kv


def mlp_block(cfg: ModelConfig, p: Mapping[str, Any], h: jax.Array):
    """Pre-norm MLP / MoE sub-block.  Returns (h, aux_loss)."""
    m_in = L.norm(h, p["norm2"], cfg.norm, cfg.norm_eps)
    if cfg.family == "moe":
        assert cfg.moe is not None
        y, aux = M.moe_mlp(m_in, p["moe"], cfg.moe)
    else:
        mlp = L.swiglu_mlp if cfg.mlp == "swiglu" else L.gelu_mlp
        y, aux = mlp(m_in, p["mlp"]), jnp.zeros((), jnp.float32)
    h = h + y
    h = shard(h, "batch", "seq", None)
    return h, aux


def ssm_block(cfg: ModelConfig, p: Mapping[str, Any], h: jax.Array,
              state: S.SSMState | None, mode: str):
    u = L.norm(h, p["norm"], cfg.norm, cfg.norm_eps)
    assert cfg.ssm is not None
    y, new_state = S.mamba2_block(u, p["ssm"], cfg.ssm, cfg.d_model,
                                  state=state, decode=(mode == "decode"))
    h = h + y
    h = shard(h, "batch", "seq", None)
    return h, new_state


# ===========================================================================
# Backbone
# ===========================================================================

def _transformer_backbone(cfg, params, h, positions, cache, mode, remat,
                          write_lens=None):
    """Scan over stacked transformer layers (dense/moe/vlm/audio).

    With a cache, the FULL stacked KV tensors ride in the scan *carry* and
    each layer dynamic-update-slices its own [1, ...] slab in place.  Passing
    them as xs/ys instead would give the loop separate input and output
    stacked buffers — 2x the KV bytes live (13 GB/device extra for
    command-r-plus decode_32k; §Perf iteration 6).
    """
    use_cache = cache is not None
    pos = cache["pos"] if use_cache else None
    # paged layout marker: the per-layer kv rides the scan carry either way,
    # shaped [slots, S, ...] dense or [num_pages, page, ...] paged
    tables = cache.get("block_tables") if use_cache else None

    aux0 = jnp.zeros((), jnp.float32)
    if use_cache:
        def body(carry, lp):
            h, aux, i, kfull, vfull = carry
            kc = jax.lax.dynamic_index_in_dim(kfull, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vfull, i, 0, keepdims=False)
            h, new_kv = attention_block(cfg, lp, h, positions, (kc, vc),
                                        pos, mode, tables=tables,
                                        write_lens=write_lens)
            kfull = jax.lax.dynamic_update_slice_in_dim(
                kfull, new_kv[0][None], i, 0)
            vfull = jax.lax.dynamic_update_slice_in_dim(
                vfull, new_kv[1][None], i, 0)
            h, aux_l = mlp_block(cfg, lp, h)
            return (h, aux + aux_l, i + 1, kfull, vfull), None

        (h, aux, _, kfull, vfull), _ = jax.lax.scan(
            body,
            (h, aux0, jnp.zeros((), jnp.int32), cache["k"], cache["v"]),
            params["layers"],
        )
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = kfull, vfull
        return h, aux, new_cache

    def body(carry, lp):
        h, aux = carry
        h, _ = attention_block(cfg, lp, h, positions, None, None, mode)
        h, aux_l = mlp_block(cfg, lp, h)
        return (h, aux + aux_l), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (h, aux), _ = jax.lax.scan(body, (h, aux0), params["layers"])
    return h, aux, None


def _ssm_backbone(cfg, params, h, cache, mode, remat):
    use_cache = cache is not None

    def body(carry, xs):
        h = carry
        if use_cache:
            lp, st = xs
            h, new_st = ssm_block(cfg, lp, h, st, mode)
        else:
            lp = xs
            h, new_st = ssm_block(cfg, lp, h, None, mode)
        return h, new_st

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )

    if use_cache:
        h, sts = jax.lax.scan(body, h, (params["layers"], cache["ssm"]))
        new_cache = dict(cache)
        new_cache["ssm"] = sts
    else:
        h, sts = jax.lax.scan(body, h, params["layers"])
        new_cache = None
    return h, jnp.zeros((), jnp.float32), new_cache


def _hybrid_backbone(cfg, params, h, positions, cache, mode, remat):
    """zamba2: segments of `period` mamba blocks, shared attention between.

    The shared attention block (weight-tied) is applied after backbone layer
    i whenever i % period == period-1, i.e. `num_layers // period` times.
    Static python structure — no lax.cond — so each application has a static
    KV-cache index.
    """
    assert cfg.hybrid is not None
    period = cfg.hybrid.period
    napps = cfg.num_attention_applications()
    use_cache = cache is not None
    pos = cache["pos"] if use_cache else None
    shared = params["shared"]

    def seg_slice(tree, lo, hi):
        return jax.tree.map(lambda x: x[lo:hi], tree)

    def run_segment(h, lo, hi, cache_seg):
        def body(carry, xs):
            hh = carry
            if use_cache:
                lp, st = xs
                hh, new_st = ssm_block(cfg, lp, hh, st, mode)
            else:
                lp = xs
                hh, new_st = ssm_block(cfg, lp, hh, None, mode)
            return hh, new_st

        bd = body
        if remat:
            bd = jax.checkpoint(bd, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (seg_slice(params["layers"], lo, hi),)
        if use_cache:
            xs = xs + (cache_seg,)
            h, sts = jax.lax.scan(bd, h, xs if len(xs) > 1 else xs[0])
            return h, sts
        h, sts = jax.lax.scan(bd, h, xs[0])
        return h, None

    def shared_app(h, kv):
        """One shared attention+MLP application (weight-tied block).
        (Wrapping this in jax.checkpoint was tried and REFUTED — zamba2
        train residency stayed ~34 GB and compile time grew 8x; the
        residency lives in the SSD chunk tensors, not these blocks.)"""
        h, new_kv = attention_block(cfg, shared, h, positions, kv, pos, mode)
        h, _ = mlp_block(cfg, shared, h)
        return h, new_kv

    new_ssm_parts = []
    new_k, new_v = (cache["k"], cache["v"]) if use_cache else (None, None)
    lo = 0
    for app in range(napps):
        hi = lo + period
        cache_seg = (jax.tree.map(lambda x: x[lo:hi], cache["ssm"])
                     if use_cache else None)
        h, sts = run_segment(h, lo, hi, cache_seg)
        if use_cache:
            new_ssm_parts.append(sts)
        # shared attention + MLP application #app
        kv = ((new_k[app], new_v[app]) if use_cache else None)
        h, new_kv = shared_app(h, kv)
        if use_cache and new_kv is not None:
            new_k = new_k.at[app].set(new_kv[0])
            new_v = new_v.at[app].set(new_kv[1])
        lo = hi
    if lo < cfg.num_layers:  # remainder backbone layers
        cache_seg = (jax.tree.map(lambda x: x[lo:], cache["ssm"])
                     if use_cache else None)
        h, sts = run_segment(h, lo, cfg.num_layers, cache_seg)
        if use_cache:
            new_ssm_parts.append(sts)

    new_cache = None
    if use_cache:
        new_cache = dict(cache)
        new_cache["ssm"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm_parts
        )
        new_cache["k"], new_cache["v"] = new_k, new_v
    return h, jnp.zeros((), jnp.float32), new_cache


def backbone(cfg, params, h, positions, cache, mode, remat=False,
             write_lens=None):
    h = shard(h, "batch", "seq", None)
    if cfg.family == "ssm":
        assert write_lens is None, "chunked prefill needs maskable KV writes"
        return _ssm_backbone(cfg, params, h, cache, mode, remat)
    if cfg.family == "hybrid":
        assert write_lens is None, "chunked prefill needs maskable KV writes"
        return _hybrid_backbone(cfg, params, h, positions, cache, mode, remat)
    return _transformer_backbone(cfg, params, h, positions, cache, mode,
                                 remat, write_lens=write_lens)


# ===========================================================================
# Heads / embedding
# ===========================================================================

def embed_tokens(cfg, params, tokens):
    w = params["embed"]["w"]
    h = jnp.take(w, tokens, axis=0)
    return h


def embed_inputs(cfg, params, batch: Mapping[str, jax.Array]):
    """Family-dependent input embedding.  Returns (h [b,s,d], positions)."""
    if cfg.family == "audio":
        frames = batch["frames"]
        if "mask" in batch:
            m = batch["mask"][..., None].astype(frames.dtype)
            frames = frames * (1 - m) + params["mask_embed"]["w"] * m
        pos = jnp.arange(frames.shape[1])[None, :]
        return frames, pos
    if cfg.family == "vlm" and "patch_embeds" in batch:
        text = embed_tokens(cfg, params, batch["tokens"])
        h = jnp.concatenate([batch["patch_embeds"].astype(text.dtype), text], axis=1)
        positions = batch["positions"]        # [b, 3, s] M-RoPE triples
        return h, positions
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    if "positions" in batch:
        return h, batch["positions"]
    return h, jnp.arange(tokens.shape[1])[None, :]


def lm_logits(cfg, params, h):
    h = L.norm(h, params["final_norm"]["w"], cfg.norm, cfg.norm_eps)
    if cfg.decoder and not cfg.tie_embeddings:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"]["w"])
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["w"])
    return shard(logits, "batch", "seq", "vocab")


def cross_entropy(logits, targets, mask):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1
    )[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll) / denom


# ===========================================================================
# Steps
# ===========================================================================

def forward_train(cfg, params, batch, *, remat=True):
    """One unjitted training forward: returns (loss, metrics)."""
    h, positions = embed_inputs(cfg, params, batch)
    h, aux, _ = backbone(cfg, params, h, positions, None, "train", remat=remat)
    logits = lm_logits(cfg, params, h)
    mask = batch.get("target_mask")
    if mask is None:
        mask = jnp.ones(batch["targets"].shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    if cfg.family == "vlm":
        # labels only exist for the text tail; pad the vision prefix out.
        pad = logits.shape[1] - batch["targets"].shape[1]
        tgt = jnp.pad(batch["targets"], ((0, 0), (pad, 0)))
        mask = jnp.pad(mask, ((0, 0), (pad, 0)))
    else:
        tgt = batch["targets"]
    ce = cross_entropy(logits, tgt, mask)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    loss = ce + aux_w * aux / max(cfg.num_layers, 1)
    return loss, {"ce": ce, "aux": aux}


def prefill(cfg, params, batch, cache):
    """Process the prompt, fill the cache, return last-position logits."""
    h, positions = embed_inputs(cfg, params, batch)
    h, _, cache = backbone(cfg, params, h, positions, cache, "prefill")
    prompt_lens = batch.get("prompt_lens")
    if prompt_lens is None:
        prompt_lens = jnp.full((h.shape[0],), h.shape[1], jnp.int32)
    cache["pos"] = prompt_lens.astype(jnp.int32)
    idx = jnp.clip(prompt_lens - 1, 0, h.shape[1] - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    logits = lm_logits(cfg, params, h_last)
    return logits[:, 0], cache


def prefill_to_slots(cfg, params, batch, cache, src):
    """Batched admission: prefill a fixed-shape batch of new requests and
    merge each into its assigned slot of the engine cache — one compiled call
    regardless of how many slots are admitted this iteration.

    batch:  {"tokens": [n, P] int32, "prompt_lens": [n] int32} — rows past
            the number of actually-admitted requests are padding (their
            results are simply never merged).
    cache:  the engine's slot cache, batch dim = max_slots.
    src:    [max_slots] int32 — src[s] = the prefill-batch row admitted into
            slot s, or -1 to leave slot s untouched.  Fixed shape, so the
            call never recompiles as the admitted set varies.

    Returns (first_tokens [max_slots] int32, cache): first_tokens[s] is the
    greedy first output token for slots with src[s] >= 0 (garbage elsewhere).
    """
    n, p_len = batch["tokens"].shape
    # The temp cache only ever holds the prompt's KV, so size it to the
    # prefill window — NOT the slot capacity (which would double peak KV
    # memory for large-capacity engines).  Stale slot KV past the prompt is
    # masked out by decode's cache_len anyway.
    if "k" in cache:
        p_len = min(p_len, cache["k"].shape[2])
    tmp = init_cache(cfg, n, p_len)
    logits, tmp = prefill(cfg, params, batch, tmp)

    take = jnp.clip(src, 0)                       # [slots] row gather index
    keep = src < 0                                # [slots] untouched slots

    def merge(old, new):
        # old: [L, slots, ...], new: [L, n, ...] — gather-by-slot then select
        gathered = jnp.take(new, take, axis=1)
        mask = keep.reshape((1, -1) + (1,) * (old.ndim - 2))
        return jnp.where(mask, old, gathered)

    def merge_head(old, new):
        # KV merge over the first p_len sequence positions only
        head = merge(old[:, :, :p_len], new)
        return old.at[:, :, :p_len].set(head)

    cache = dict(cache)
    for key in ("k", "v"):
        if key in cache:
            cache[key] = merge_head(cache[key], tmp[key])
    if "ssm" in cache:
        cache["ssm"] = jax.tree.map(merge, cache["ssm"], tmp["ssm"])
    cache["pos"] = jnp.where(keep, cache["pos"], jnp.take(tmp["pos"], take))
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # [n]
    first_slots = jnp.where(keep, -1, jnp.take(first, take))
    return first_slots, cache


def prefill_to_pages(cfg, params, batch, cache, src):
    """Batched admission into the PAGED cache: prefill a fixed-shape batch
    and scatter each admitted request's prompt KV onto its block-table
    pages — one compiled call per admission wave, same contract as
    `prefill_to_slots`.

    batch/src: as in `prefill_to_slots` (src[s] = prefill row admitted into
    slot s, or -1).  cache: a paged cache from `init_paged_cache`, whose
    `block_tables` rows for admitted slots already map enough pages to hold
    the prompt (the engine's allocator guarantees this before calling).

    Rows the mask rejects — padding slots and positions past a prompt's
    length — are redirected to the shared garbage page 0, so the scatter
    stays fixed-shape without ever touching live pages.
    """
    n, p_len = batch["tokens"].shape
    slots, max_blocks = cache["block_tables"].shape
    page_size = cache["k"].shape[2]
    tmp = init_cache(cfg, n, p_len)
    logits, tmp = prefill(cfg, params, batch, tmp)

    take = jnp.clip(src, 0)                       # [slots] row gather index
    keep = src < 0                                # [slots] untouched slots
    tables = cache["block_tables"]

    tok = jnp.broadcast_to(jnp.arange(p_len)[None, :], (slots, p_len))
    lens = jnp.take(batch["prompt_lens"], take)                  # [slots]
    valid = (~keep)[:, None] & (tok < lens[:, None])             # [slots, P]
    blk = jnp.clip(tok // page_size, 0, max_blocks - 1)
    phys = jnp.take_along_axis(tables, blk, axis=1)              # [slots, P]
    phys = jnp.where(valid, phys, 0)              # rejected rows -> garbage
    row = tok % page_size

    cache = dict(cache)
    for key in ("k", "v"):
        new = jnp.take(tmp[key], take, axis=1)    # [L, slots, P, nkv, hd]
        cache[key] = cache[key].at[:, phys, row].set(new)
    cache["pos"] = jnp.where(keep, cache["pos"], jnp.take(tmp["pos"], take))
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # [n]
    first_slots = jnp.where(keep, -1, jnp.take(first, take))
    return first_slots, cache


def prefill_chunk(cfg, params, cache, tokens, chunk_lens):
    """One wave of CHUNKED prefill: feed a [max_slots, P] window of prompt
    tokens through the decode path at each slot's current cache position.

    Admission splits any prompt longer than the compiled prefill window into
    P-token chunks.  Chunk 0 goes through `prefill_to_slots` /
    `prefill_to_pages` (positions 0..P-1); every later chunk goes through
    this entry point, which

      * embeds the window at per-slot ABSOLUTE positions ``cache["pos"] + j``
        (RoPE must see prompt offsets, not 0..P-1);
      * runs the backbone in decode mode, so each chunk token attends to all
        previously-written KV plus its own chunk prefix — exactly the
        one-shot prefill's causal mask restricted to this window;
      * writes KV at the running offset, masked per slot to the first
        ``chunk_lens[s]`` tokens (dense: out-of-window scatter indices are
        dropped; paged: they land on the shared garbage page), so the ragged
        final chunk and the slots NOT chunking this wave (live decodes,
        idle slots — rows with ``chunk_lens[s] == 0``) never touch live
        cache entries;
      * advances ``cache["pos"]`` by ``chunk_lens`` (0 leaves a slot put).

    Fixed shapes throughout: one compiled program serves every wave of
    every admission, like `prefill_to_slots`.

    Returns ``(next_tok, cache)``: ``next_tok[s]`` is the greedy token
    following the last valid position of slot s's chunk — the request's
    first output token when this was its final chunk (garbage for rows with
    ``chunk_lens[s] == 0``; the engine only reads rows it finalized).

    Bit-identity contract: a prompt admitted through these waves produces
    exactly the one-shot prefill's logits (asserted against the raw-model
    oracle in `tests/test_serving_chunked.py`).  The engine's preemption
    path leans on this — a preempted request is requeued as
    ``prompt + tokens-so-far`` and recomputed through THIS entry point, so
    its continuation token equals the decode step the preemption skipped
    and the caller-visible stream is unchanged.
    """
    logits, cache = chunk_logits(cfg, params, cache, tokens, chunk_lens)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, cache


def chunk_logits(cfg, params, cache, tokens, chunk_lens):
    """`prefill_chunk`'s body, stopping at the last-valid-position logits.

    Returns ``(logits [slots, V], cache)`` where ``logits[s]`` is the
    distribution after the last valid token of slot s's chunk (garbage for
    rows with ``chunk_lens[s] == 0``).  ``prefill_chunk`` is exactly
    ``argmax(chunk_logits(...))``; the continuous-batching serve loop calls
    this directly so it can fold fault injection between the logits and the
    argmax inside ONE jitted program (see `serving/engine.py`)."""
    b, t = tokens.shape
    pos = cache["pos"]
    positions = pos[:, None] + jnp.arange(t)[None, :]
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[:, None, :], (b, 3, t))
    h, positions = embed_inputs(cfg, params, {"tokens": tokens,
                                              "positions": positions})
    h, _, cache = backbone(cfg, params, h, positions, cache, "decode",
                           write_lens=chunk_lens)
    idx = jnp.clip(chunk_lens - 1, 0, t - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    logits = lm_logits(cfg, params, h_last)
    cache = dict(cache)
    cache["pos"] = pos + chunk_lens.astype(jnp.int32)
    return logits[:, 0], cache


def mixed_step(cfg, params, cache, tokens, chunk_lens, pin_mask, pin_pos):
    """One continuous-batching wave: prefill chunks AND single-token decodes
    in the SAME device program.

    A decode is just a chunk of length 1 — row s with ``chunk_lens[s] == 1``
    holding the slot's last committed token attends to everything written so
    far plus itself, writes one KV entry at ``pos``, and its logits row is
    the next-token distribution, bit-identical to `decode_step` on that slot
    (same backbone ops on the same cache values).  So the serve loop packs
    newly admitted requests' prompt chunks and ongoing decodes into one
    ``[slots, P]`` window and dispatches a single program per iteration —
    the NeuPIMs-style mixed prefill/decode sub-batch, in software.

    ``pin_mask`` / ``pin_pos`` repair host-tracked prefill offsets: while a
    slot is mid-prefill it also rides every *other* program the engine
    dispatches (speculative verify, the plain fused step) as a masked
    garbage row whose ``cache["pos"]`` drifts.  The wave re-anchors those
    rows to the host's authoritative chunk offset before embedding
    (``where(pin_mask, pin_pos, pos)``); decoding rows keep the
    device-resident position.

    Returns ``(logits [slots, V], cache)`` exactly like `chunk_logits`.
    """
    cache = dict(cache)
    cache["pos"] = jnp.where(pin_mask, pin_pos,
                             cache["pos"]).astype(jnp.int32)
    return chunk_logits(cfg, params, cache, tokens, chunk_lens)


def decode_step(cfg, params, cache, tokens, positions=None):
    """tokens [b, t] -> (logits [b, t, V], new cache).  t = TLP (1 for the
    dry-run serve_step; >1 verifies a speculative window)."""
    b, t = tokens.shape[0], tokens.shape[1]
    pos = cache["pos"]
    if positions is None:
        positions = pos[:, None] + jnp.arange(t)[None, :]
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, t))
    batch = {"tokens": tokens, "positions": positions}
    h, positions = embed_inputs(cfg, params, batch)
    h, _, cache = backbone(cfg, params, h, positions, cache, "decode")
    logits = lm_logits(cfg, params, h)
    cache["pos"] = pos + t
    return logits, cache
