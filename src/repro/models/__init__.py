from repro.models.model import (
    cache_logical_axes,
    cache_shardings,
    decode_step,
    forward_train,
    init_cache,
    init_params,
    lm_logits,
    param_logical_axes,
    param_shapes,
    param_shardings,
    prefill,
    prefill_to_slots,
)

__all__ = [
    "cache_logical_axes",
    "cache_shardings",
    "decode_step",
    "forward_train",
    "init_cache",
    "init_params",
    "lm_logits",
    "param_logical_axes",
    "param_shapes",
    "param_shardings",
    "prefill",
    "prefill_to_slots",
]
