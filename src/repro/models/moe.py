"""Mixture-of-Experts layer: top-k router + capacity-based one-hot dispatch.

GShard/Switch-style static-shape dispatch so the layer lowers cleanly in the
multi-pod dry-run (no dynamic shapes): tokens are routed into a
[experts, capacity] buffer via einsum with a dispatch one-hot; overflow
tokens are dropped (their combine weight is zero) — standard capacity-factor
semantics.

Expert weights are stacked [E, ...] and sharded over the `model` mesh axis
(expert parallelism).  The PAPI connection (§6.5 of the paper): the per-expert
parallelism is RLP*TLP*top_k/E, so experts stay memory-bound far longer than
a dense FFN — `core.scheduler` uses exactly this corrected parallelism for
MoE archs.
"""
from __future__ import annotations

import math
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.sharding import shard

Params = Mapping[str, jax.Array]


# Tokens are dispatched within fixed-size groups: the one-hot dispatch tensor
# is [g, GROUP, E, C] — quadratic in group size — so grouping caps its memory
# at ~40MB/group regardless of global batch (GShard's "G" dimension).
GROUP_SIZE = 1024


def expert_capacity(num_tokens: int, cfg: MoEConfig) -> int:
    if num_tokens <= 2048:
        # Decode-scale groups: full capacity => token drops are impossible
        # (serving must be lossless; PAPI does not approximate).
        return num_tokens
    cap = math.ceil(num_tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    # Keep lane-friendly: round up to a multiple of 8 (min 8).
    return max(8, (cap + 7) // 8 * 8)


def router(x: jax.Array, w_router: jax.Array, cfg: MoEConfig):
    """x: [tokens, d] -> (top-k expert ids [tokens, k], weights [tokens, k],
    full router probs [tokens, E] for the aux loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    # OLMoE/granite-moe normalize the top-k weights to sum to one.
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    return top_e, top_w, probs


def load_balancing_loss(probs: jax.Array, top_e: jax.Array, num_experts: int) -> jax.Array:
    """Switch-transformer aux loss: E * sum_e f_e * P_e."""
    tokens = probs.shape[0]
    occupancy = jax.nn.one_hot(top_e, num_experts, dtype=jnp.float32)  # [t, k, E]
    f = jnp.sum(occupancy, axis=(0, 1)) / (tokens * top_e.shape[1])
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def _dispatch_tensors(top_e: jax.Array, top_w: jax.Array, cfg: MoEConfig,
                      capacity: int):
    """Build dispatch one-hot [t, E, C] and combine weights [t, E, C]."""
    t, k = top_e.shape
    e_onehot = jax.nn.one_hot(top_e, cfg.num_experts, dtype=jnp.float32)  # [t,k,E]
    # Position of each (token, k) assignment within its expert's buffer:
    # cumulative count over the flattened (k-major, token-minor) order.
    flat = e_onehot.transpose(1, 0, 2).reshape(t * k, cfg.num_experts)    # [k*t, E]
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat                       # [k*t, E]
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(k, t).T          # [t, k]
    keep = (pos < capacity).astype(jnp.float32)
    pos_onehot = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                                dtype=jnp.float32)                        # [t,k,C]
    dispatch = jnp.einsum("tke,tkc,tk->tec", e_onehot, pos_onehot, keep)
    combine = jnp.einsum("tec,tke,tk->tec", dispatch, e_onehot, top_w)
    return dispatch, combine


def moe_mlp(x: jax.Array, p: Params, cfg: MoEConfig):
    """x: [b, s, d] -> (y [b, s, d], aux_loss scalar).

    p: w_router [d, E]; w_gate/w_up [E, d, f]; w_down [E, f, d].

    Tokens are flattened and split into GROUP_SIZE groups (the group axis
    aligns with the batch axis when s % GROUP_SIZE == 0, so it shards over
    `data` alongside activations).
    """
    b, s, d = x.shape
    tokens = b * s
    gs = min(GROUP_SIZE, tokens)
    assert tokens % gs == 0, f"{tokens} tokens not divisible by group {gs}"
    g = tokens // gs
    xt = x.reshape(g, gs, d)

    top_e, top_w, probs = jax.vmap(lambda xg: router(xg, p["w_router"], cfg))(xt)
    aux = jnp.mean(
        jax.vmap(lambda pr, te: load_balancing_loss(pr, te, cfg.num_experts))(
            probs, top_e
        )
    )
    capacity = expert_capacity(gs, cfg)
    dispatch, combine = jax.vmap(
        lambda te, tw: _dispatch_tensors(te, tw, cfg, capacity)
    )(top_e, top_w)                                       # [g, gs, E, C]

    # [g, E, C, d] expert inputs; experts sharded over `model` (EP), group
    # (≈ batch) over `data`.
    xin = jnp.einsum("gtd,gtec->gecd", xt, dispatch.astype(x.dtype))
    xin = shard(xin, "batch", "act_experts", None, None)
    gate = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    yout = jnp.einsum("gecf,efd->gecd", act, p["w_down"])
    yout = shard(yout, "batch", "act_experts", None, None)
    y = jnp.einsum("gecd,gtec->gtd", yout, combine.astype(x.dtype))
    return y.reshape(b, s, d), aux
