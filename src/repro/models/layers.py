"""Transformer building blocks: norms, RoPE/M-RoPE, MLPs, attention.

Everything is a pure function over explicit parameter dicts (no framework).
Attention comes in three flavors:
  * `flash_attention`  — blockwise online-softmax attention (pure JAX scan),
    used for training / prefill so a 32k x 32k score matrix is never
    materialized.  This is the XLA path; the Pallas TPU kernel in
    `repro.kernels` implements the same math for the decode hot-spot.
  * `decode_attention` — one (or TLP) query tokens against a KV cache.
  * dense fallback for tiny smoke shapes.

Numerics policy: matmuls run in the params' dtype (bf16 on the production
path), softmax/normalization statistics accumulate in f32.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_mesh, shard
from repro.models.linear import papi_linear

Params = Mapping[str, jax.Array]

_attn_state = threading.local()


def current_attn_impl() -> str:
    """Decode-attention implementation: "xla" (default softmax path) or
    "pim" (the Pallas flash-decode kernel — the Attn-PIM analogue, sharded
    one unit per KV shard when a mesh is installed)."""
    return getattr(_attn_state, "impl", "xla")


@contextlib.contextmanager
def attn_impl(impl: str):
    assert impl in ("xla", "pim"), impl
    prev = current_attn_impl()
    _attn_state.impl = impl
    try:
        yield
    finally:
        _attn_state.impl = prev


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    # statistics in f32; the DATAPATH stays in the params' dtype.  Keeping
    # the normalized tensor bf16 halves the backward's weight-grad
    # activation all-gathers under sequence parallelism (§Perf iteration 2).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    # explicit broadcast: keeps the sanitizer's rank-promotion-raise happy
    w = jnp.reshape(weight.astype(x.dtype), (1,) * (x.ndim - 1) + (-1,))
    return x * inv * w


def layernorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Scale-only LayerNorm (bias-free, matching our parameter accounting)."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    w = jnp.reshape(weight.astype(x.dtype), (1,) * (x.ndim - 1) + (-1,))
    return ((x.astype(jnp.float32) - mean) * inv).astype(x.dtype) * w


def norm(x: jax.Array, weight: jax.Array, kind: str, eps: float) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, weight, eps)
    return layernorm(x, weight, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + qwen2-vl's M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2] (f32)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                          # [hd/2]
    pos = positions[..., None].astype(jnp.float32)       # [..., seq, 1]
    angles = pos * jnp.reshape(inv, (1,) * (pos.ndim - 1) + (-1,))
    angles = angles[..., None, :]                        # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(
    x: jax.Array,
    positions: jax.Array,        # [..., 3, seq] (temporal, height, width)
    theta: float,
    sections: tuple[int, ...],   # frequency split of hd/2, sums to hd/2
) -> jax.Array:
    """qwen2-vl multimodal RoPE: hd/2 frequency slots are partitioned into
    (temporal, height, width) sections, each rotated by its own position id."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                          # [hd/2]
    # Per-frequency slot: which of the 3 position streams rotates it.
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=hd // 2
    )                                                    # [hd/2] in {0,1,2}
    pos = jnp.take(positions, sec_id, axis=-2)           # [..., hd/2, seq]
    pos = jnp.swapaxes(pos, -1, -2).astype(jnp.float32)  # [..., seq, hd/2]
    inv_b = jnp.reshape(inv, (1,) * (pos.ndim - 1) + (-1,))
    angles = (pos * inv_b)[..., None, :]                 # [..., seq, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_mlp(x: jax.Array, p: Params) -> jax.Array:
    """LLaMA-style gated MLP: down( silu(gate(x)) * up(x) )."""
    gate = papi_linear(x, p["w_gate"], tp="col")
    up = papi_linear(x, p["w_up"], tp="col")
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    act = shard(act, None, None, "act_ffn")
    return papi_linear(act, p["w_down"], tp="row")


def gelu_mlp(x: jax.Array, p: Params) -> jax.Array:
    """GPT-style 2-layer MLP with biases."""
    h = papi_linear(x, p["w_in"], tp="col") + p["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    h = shard(h, None, None, "act_ffn")
    return papi_linear(h, p["w_out"], tp="row") + p["b_out"]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def qkv_project(
    x: jax.Array,
    p: Params,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """[b, s, d] -> q[b, s, nH, hd], k/v[b, s, nKV, hd]."""
    b, s, d = x.shape

    def proj(w):  # [d, nh, hd] applied through the scheduled FC path
        nh, hd = w.shape[1], w.shape[2]
        # the K/V weights' logical bank dim is "kv_heads" (for MHA every
        # projection is, matching their stored ("kv_heads" -> replicated)
        # layout); only GQA's query weight banks over "heads"
        bank = "kv_heads" if nh == num_kv_heads else "heads"
        return papi_linear(x, w.reshape(d, nh * hd), tp="col", bank=bank,
                           units=nh).reshape(b, s, nh, hd)

    q, k, v = proj(p["w_q"]), proj(p["w_k"]), proj(p["w_v"])
    if "b_q" in p:
        q = q + p["b_q"][None, None]
        k = k + p["b_k"][None, None]
        v = v + p["b_v"][None, None]
    # Re-shard at the attention boundary ONCE per layer: heads over `model`
    # where divisible (TP attention), otherwise an explicit seq-gather here.
    # Without this constraint the seq(SP)-sharded K/V flow into the blocked
    # flash loops and XLA all-gathers them per (q-block x kv-block)
    # iteration — x6144 collective multipliers in the dry-run.
    q = shard(q, "batch", None, "act_heads", None)
    k = shard(k, "batch", None, "act_kv_heads", None)
    v = shard(v, "batch", None, "act_kv_heads", None)
    return q, k, v


def out_project(attn: jax.Array, p: Params) -> jax.Array:
    """[b, s, nH, hd] -> [b, s, d]."""
    b, s, nh, hd = attn.shape
    w = p["w_o"]
    return papi_linear(attn.reshape(b, s, nh * hd), w.reshape(nh * hd, -1),
                       tp="row", bank="heads", units=nh)


def _repeat_kv(k: jax.Array, group: int) -> jax.Array:
    """[b, s, nKV, hd] -> [b, s, nKV*group, hd] for GQA."""
    if group == 1:
        return k
    b, s, nkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, nkv, group, hd))
    return k.reshape(b, s, nkv * group, hd)


def dense_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool
) -> jax.Array:
    """Reference attention, materializes [b, h, sq, sk].  Smoke shapes only."""
    group = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, group), _repeat_kv(v, group)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def expand_kv_heads(k: jax.Array, nh: int) -> jax.Array:
    """GQA KV expansion via a static head-index gather: [b,s,nKV,hd] ->
    [b,s,nH,hd].  Unlike a (nkv, group) reshape of the query tensor, the
    gather keeps the TP-sharded head dim intact for ANY group size (96 heads
    / 16 shards works even though 96 = 8 KV x 12 group is per-dim
    indivisible), so no all-gather is provoked under tensor parallelism."""
    nkv = k.shape[2]
    if nkv == nh:
        return k
    idx = jnp.arange(nh) // (nh // nkv)
    return jnp.take(k, idx, axis=2)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Blockwise online-softmax attention (pure JAX).

    Peak score memory is [b, heads, q_block, kv_block] instead of [sq, sk].
    GQA KV heads are expanded by static gather (see expand_kv_heads) so the
    whole computation stays cleanly sharded over the head dim.
    """
    b, sq, nh, hd = q.shape
    sk = k.shape[1]
    if sq % q_block or sk % kv_block:
        # Fall back for ragged smoke shapes.
        return dense_attention(q, k, v, causal=causal)
    k = expand_kv_heads(k, nh)
    v = expand_kv_heads(v, nh)
    scale = 1.0 / math.sqrt(hd)
    nqb, nkb = sq // q_block, sk // kv_block

    qg = q.reshape(b, nqb, q_block, nh, hd)
    kb = k.reshape(b, nkb, kv_block, nh, hd)
    vb = v.reshape(b, nkb, kv_block, nh, hd)
    q_pos = jnp.arange(sq).reshape(nqb, q_block)
    k_pos = jnp.arange(sk).reshape(nkb, kv_block)

    def per_qblock(qi: jax.Array, qblk: jax.Array) -> jax.Array:
        # qblk: [b, qb, nh, hd]
        acc0 = jnp.zeros((b, q_block, nh, hd), jnp.float32)
        m0 = jnp.full((b, q_block, nh), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, q_block, nh), jnp.float32)

        def body(carry, inp):
            acc, m, l = carry
            ki, kblk, vblk = inp
            s = jnp.einsum("bqhk,bshk->bqhs", qblk, kblk).astype(jnp.float32)
            s = s * scale
            if causal:
                mask = q_pos[qi][:, None] >= k_pos[ki][None, :]
                s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # Guard fully-masked rows (m_new = -inf): exp(-inf - -inf) = nan.
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            alpha = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhs,bshk->bqhk", p.astype(v.dtype), vblk)
            acc = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (acc, m_new, l), None

        ks = jnp.arange(nkb)
        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0),
            (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    outs = jax.lax.map(
        lambda args: per_qblock(*args),
        (jnp.arange(nqb), jnp.moveaxis(qg, 1, 0)),
    )                                                     # [nqb, b, qb, nh, hd]
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, nh, hd)
    return out


def decode_attention_xla(
    q: jax.Array,        # [b, t, nH, hd] (t = TLP query tokens)
    k_cache: jax.Array,  # [b, S, nKV, hd]
    v_cache: jax.Array,  # [b, S, nKV, hd]
    cache_len: jax.Array | int,   # valid prefix length (new tokens included)
    q_offset: jax.Array | int = 0,  # absolute position of q[0] in the stream
) -> jax.Array:
    """Decode attention against a (padded) KV cache — XLA path.

    `cache_len` / `q_offset` may be scalars or per-request [b] arrays
    (continuous batching => ragged positions).  Positions >= cache_len are
    masked; within the t query tokens the mask is causal from `q_offset`.
    """
    b, t, nh, hd = q.shape
    skv, nkv = k_cache.shape[1], k_cache.shape[2]
    group = nh // nkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, t, nkv, group, hd)
    s = jnp.einsum("bthgk,bshk->bthgs", qg, k_cache).astype(jnp.float32) * scale
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    kv_pos = jnp.arange(skv)
    q_pos = q_offset[:, None] + jnp.arange(t)[None, :]          # [b, t]
    valid = (kv_pos[None, None, :] <= q_pos[..., None]) & (
        kv_pos[None, None, :] < cache_len[:, None, None]
    )                                                            # [b, t, skv]
    s = jnp.where(valid[:, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bthgs,bshk->bthgk", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, t, nh, hd)


def gather_kv_pages(pages: jax.Array, tables: jax.Array) -> jax.Array:
    """Materialize a contiguous per-slot KV view from the page pool:
    [num_pages, page, nkv, hd] gathered by [b, max_blocks] block tables ->
    [b, max_blocks * page, nkv, hd].  This is the XLA decode path for the
    paged cache — since the Pallas kernels went windowed it is OFF the
    jitted hot path under attn_impl("pim") and survives as the tested
    bit-identity oracle (the paged kernel performs the same gather inside
    its index_map without ever building this view)."""
    b, nblk = tables.shape
    _, page, nkv, hd = pages.shape
    g = jnp.take(pages, tables, axis=0)          # [b, nblk, page, nkv, hd]
    return g.reshape(b, nblk * page, nkv, hd)


def _fold_query_window(q: jax.Array, nkv: int) -> jax.Array:
    """[b, t, nH, hd] -> the kernels' [b, nkv, t*g, hd] row layout: rows are
    (window, group)-row-major within each KV head (row = r * g + gg), the
    order the windowed kernels' intra-window causal mask assumes."""
    b, t, nh, hd = q.shape
    g = nh // nkv
    qh = q.reshape(b, t, nkv, g, hd).transpose(0, 2, 1, 3, 4)
    return qh.reshape(b, nkv, t * g, hd)


def _unfold_query_window(out: jax.Array, t: int, nh: int) -> jax.Array:
    """Inverse of `_fold_query_window`: [b, nkv, t*g, hd] -> [b, t, nH, hd]."""
    b, nkv, tg, hd = out.shape
    o = out.reshape(b, nkv, t, tg // t, hd).transpose(0, 2, 1, 3, 4)
    return o.reshape(b, t, nh, hd)


def decode_attention_pim_paged(
    q: jax.Array,        # [b, t, nH, hd] — t >= 1 query-window rows
    k_pages: jax.Array,  # [num_pages, page, nKV, hd]
    v_pages: jax.Array,  # [num_pages, page, nKV, hd]
    tables: jax.Array,   # [b, max_blocks] int32 block tables
    lens: jax.Array,     # [b] valid lengths (ALL t window tokens included)
) -> jax.Array:
    """Paged decode attention through the block-table Pallas kernel — the
    Attn-PIM path over bank-row pages, for any TLP t >= 1 (plain decode,
    speculative verify windows, chunked-prefill waves).  The t window rows
    sit at consecutive absolute positions `lens - t .. lens - 1`
    (intra-window causal mask inside the kernel); no contiguous page view is
    ever materialized.  Under a mesh the kernel shard_maps over KV heads
    exactly like the dense `decode_attention_pim` (tables and lens
    replicate; each head shard holds the full page pool for its heads)."""
    from repro.kernels.paged_decode_attention import (
        paged_decode_attention, paged_decode_attention_sharded)
    b, t, nh, hd = q.shape
    nkv = k_pages.shape[2]
    qh = _fold_query_window(q, nkv)
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (b,))
    mesh = current_mesh()
    if mesh is not None:
        out = paged_decode_attention_sharded(qh, k_pages, v_pages, lens,
                                             tables, mesh=mesh, q_rows=t)
    else:
        out = paged_decode_attention(qh, k_pages, v_pages, lens, tables,
                                     q_rows=t)
    return _unfold_query_window(out, t, nh)


def decode_attention_pim(
    q: jax.Array,        # [b, t, nH, hd] — t >= 1 query-window rows
    k_cache: jax.Array,  # [b, S, nKV, hd]
    v_cache: jax.Array,  # [b, S, nKV, hd]
    lens: jax.Array,     # [b] valid lengths (ALL t window tokens included)
) -> jax.Array:
    """Decode attention through the Pallas flash-decode kernel — the
    Attn-PIM path, for any TLP t >= 1 (plain decode, speculative verify
    windows, chunked-prefill waves).  The t window rows sit at consecutive
    absolute positions `lens - t .. lens - 1`; the kernel applies the
    intra-window causal mask.  Under a mesh the kernel is `shard_map`-split
    over KV heads (one Attn-PIM unit per KV shard, see
    `kernels.decode_attention_sharded`); head layout matches
    `decode_attention_xla`'s GQA grouping (head = kv * group + g)."""
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_sharded)
    b, t, nh, hd = q.shape
    nkv = k_cache.shape[2]
    qh = _fold_query_window(q, nkv)
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (b,))
    mesh = current_mesh()
    if mesh is not None:
        out = decode_attention_sharded(qh, k_cache, v_cache, lens, mesh=mesh,
                                       q_rows=t)
    else:
        out = decode_attention(qh, k_cache, v_cache, lens, q_rows=t)
    return _unfold_query_window(out, t, nh)
