from repro.training.checkpoint import CheckpointManager
from repro.training.compression import (
    compress,
    compress_with_feedback,
    decompress,
    init_error,
)
from repro.training.optim import (
    AdamWConfig,
    AdamWState,
    adamw_update,
    init_adamw,
    lr_schedule,
    zero1_logical_axes,
)
from repro.training.train_loop import (
    TrainConfig,
    TrainResult,
    make_train_step,
    run_training,
)
from repro.training.watchdog import StepWatchdog, StragglerEvent

__all__ = [
    "AdamWConfig", "AdamWState", "CheckpointManager", "StepWatchdog",
    "StragglerEvent", "TrainConfig", "TrainResult", "adamw_update",
    "compress", "compress_with_feedback", "decompress", "init_adamw",
    "init_error", "lr_schedule", "make_train_step", "run_training",
    "zero1_logical_axes",
]
