"""AdamW from scratch (pytree ops, no optax) with ZeRO-1 state sharding.

State layout: m/v in f32 regardless of param dtype (mixed-precision master
statistics).  `zero1_logical_axes` assigns the optimizer states an extra
`fsdp` (-> data-axis) sharding on their first shardable dim when the params
themselves are replicated over data — the ZeRO-1 trick: each data shard owns
a slice of the optimizer state and the update, weights stay replicated.
When the rule table already shards params over `fsdp` (FSDP/ZeRO-3 mode)
states simply inherit the param sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: PyTree                # first moment (f32)
    v: PyTree                # second moment (f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_adamw(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig,
    params: PyTree,
    grads: PyTree,
    state: AdamWState,
) -> tuple[PyTree, AdamWState, dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard LLM practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics


def zero1_logical_axes(param_axes: PyTree, param_shapes: PyTree) -> PyTree:
    """Logical axes for optimizer states (ZeRO-1).

    If a param already has an `fsdp` axis, states inherit it.  Otherwise the
    first dim not already mapped to the `model` family gets `fsdp`, sharding
    the state (and its update) across the data axis.
    """
    def st_axes(axes, shape):
        axes = tuple(axes)
        if "fsdp" in axes:
            return axes
        out = list(axes)
        for i, (a, d) in enumerate(zip(axes, shape)):
            if a is None and d >= 64:      # shardable dim
                out[i] = "fsdp"
                break
        return tuple(out)

    return jax.tree.map(
        lambda a, s: st_axes(a, s.shape), param_axes, param_shapes,
        is_leaf=lambda x: isinstance(x, tuple),
    )
