"""Checkpointing: async save, elastic restore, preemption handling.

Checkpoints are host-gathered numpy archives (one .npz per pytree plus a
JSON manifest), so a restart may use a *different* mesh shape: restore
device_puts each leaf under the new sharding (elastic re-sharding on load).
Saves run on a background thread (async: the step loop never blocks on
disk); a SIGTERM (preemption) triggers a final synchronous checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._preempted = False

    # ---------------------------------------------------------------- save
    def save(self, step: int, trees: dict[str, PyTree],
             blocking: bool = False) -> None:
        """Snapshot to host memory NOW, write to disk asynchronously."""
        host = {name: _flatten_with_paths(t) for name, t in trees.items()}
        self.wait()                      # one in-flight save at a time

        def write():
            path = os.path.join(self.directory, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            for name, flat in host.items():
                np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "trees": sorted(host),
                           "time": time.time()}, f)
            # idempotent publish: re-saving a step (resume overlap,
            # preemption double-fire) replaces the previous snapshot
            if os.path.exists(path):
                shutil.rmtree(path)
            os.replace(tmp, path)        # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            path = os.path.join(self.directory, f"step_{s:08d}")
            for root, dirs, files in os.walk(path, topdown=False):
                for fn in files:
                    os.unlink(os.path.join(root, fn))
                for d in dirs:
                    os.rmdir(os.path.join(root, d))
            os.rmdir(path)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, templates: dict[str, PyTree],
                shardings: dict[str, PyTree] | None = None) -> dict[str, PyTree]:
        """Restore into the structure of `templates`.  If `shardings` is
        given, each leaf is device_put under its (possibly new-mesh)
        sharding — elastic restore."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        out: dict[str, PyTree] = {}
        for name, template in templates.items():
            data = np.load(os.path.join(path, f"{name}.npz"))
            flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
            leaves = []
            shard_tree = shardings.get(name) if shardings else None
            flat_s = (treedef.flatten_up_to(shard_tree)
                      if shard_tree is not None else [None] * len(flat_t))
            for (pth, leaf), shd in zip(flat_t, flat_s):
                key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                               for p in pth)
                arr = data[key]
                assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
                if shd is not None:
                    leaves.append(jax.device_put(arr.astype(leaf.dtype), shd))
                else:
                    leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
            out[name] = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), leaves
            )
        return out

    # ----------------------------------------------------------- preemption
    def install_preemption_handler(self, save_fn: Callable[[], None]) -> None:
        """On SIGTERM: write a final blocking checkpoint, then re-raise."""
        prev = signal.getsignal(signal.SIGTERM)

        def handler(signum, frame):
            self._preempted = True
            save_fn()
            if callable(prev):
                prev(signum, frame)

        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted
