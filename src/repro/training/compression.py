"""Int8 gradient compression with error feedback.

At 1000+ node scale the DP all-reduce dominates the step's collective bytes;
int8 compression cuts them 2x vs bf16 (4x vs f32).  Error feedback keeps the
asymptotic convergence: the quantization residual is carried into the next
step's gradient, so the compression bias telescopes away.

The compress/decompress pair brackets the gradient all-reduce: on a real
mesh the int8 payload is what crosses ICI (wired into the train step when
`compress_grads=True`); numerically the composition is what we test.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization: returns (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(
    grads: PyTree, error: PyTree
) -> tuple[PyTree, PyTree]:
    """Quantize (grads + carried error); return (dequantized grads, new
    error).  The returned grads are what the all-reduce transports."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        deq = decompress(q, s)
        return deq, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
