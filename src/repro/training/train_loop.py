"""The training driver: jitted step with microbatch gradient accumulation,
AdamW (ZeRO-1-shardable states), optional int8 grad compression with error
feedback, async checkpointing with elastic restore, preemption handling and
a straggler watchdog.

`make_train_step(cfg, opt)` builds one jit-compilable function
    (params, opt_state, err, batch) -> (params, opt_state, err, metrics)
where `batch` leaves carry a leading [accum] microbatch axis that a
lax.scan accumulates over — one optimizer application per global step.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.models import forward_train, init_params
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import compress_with_feedback, init_error
from repro.training.optim import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.training.watchdog import StepWatchdog

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    accum: int = 1
    remat: bool = True
    compress_grads: bool = False
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0


def make_train_step(
    mcfg: ModelConfig,
    ocfg: AdamWConfig,
    *,
    accum: int = 1,
    remat: bool = True,
    compress_grads: bool = False,
) -> Callable:
    def loss_fn(params, microbatch):
        loss, metrics = forward_train(mcfg, params, microbatch, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, err: PyTree, batch):
        if accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), batch)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            (loss, _), grads = grad_fn(params, batch)

        if compress_grads:
            # int8 + error feedback brackets the DP all-reduce
            grads, err = compress_with_feedback(grads, err)

        params, opt_state, om = adamw_update(ocfg, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, err, metrics

    return train_step


@dataclasses.dataclass
class TrainResult:
    losses: list[float]
    final_step: int
    straggler_events: int
    resumed_from: int | None


def run_training(
    mcfg: ModelConfig,
    tcfg: TrainConfig,
    dcfg: DataConfig,
    ocfg: AdamWConfig | None = None,
    resume: bool = False,
) -> TrainResult:
    """Single-host end-to-end loop (the multi-pod version lowers the same
    train_step through launch.train with mesh shardings)."""
    ocfg = ocfg or AdamWConfig(total_steps=tcfg.steps)
    ckpt = CheckpointManager(tcfg.checkpoint_dir)

    params = init_params(mcfg, jax.random.PRNGKey(tcfg.seed))
    opt_state = init_adamw(params)
    err = init_error(params) if tcfg.compress_grads else {}
    start_step = 0
    resumed_from = None

    if resume and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        restored = ckpt.restore(
            start_step,
            {"params": params, "opt": opt_state},
        )
        params, opt_state = restored["params"], restored["opt"]
        resumed_from = start_step

    step_fn = jax.jit(
        make_train_step(mcfg, ocfg, accum=tcfg.accum, remat=tcfg.remat,
                        compress_grads=tcfg.compress_grads),
        donate_argnums=(0, 1, 2),
    )

    def save(step: int, blocking: bool = False) -> None:
        ckpt.save(step, {"params": params, "opt": opt_state},
                  blocking=blocking)

    ckpt.install_preemption_handler(lambda: save(start_step, blocking=True))
    watchdog = StepWatchdog()
    losses: list[float] = []

    for step in range(start_step, tcfg.steps):
        watchdog.start_step(step)
        raw = make_batch(mcfg, dcfg, step)
        if tcfg.accum > 1:
            raw = jax.tree.map(
                lambda x: x.reshape((tcfg.accum, x.shape[0] // tcfg.accum)
                                    + x.shape[1:]),
                raw,
            )
        batch = jax.tree.map(jnp.asarray, raw)
        params, opt_state, err, metrics = step_fn(params, opt_state, err, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        watchdog.end_step()

        if (step + 1) % tcfg.checkpoint_every == 0 or step + 1 == tcfg.steps:
            save(step + 1)
        if (step + 1) % tcfg.log_every == 0:
            print(f"step {step+1:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
    ckpt.wait()
    return TrainResult(losses, tcfg.steps, len(watchdog.events), resumed_from)
