"""Straggler watchdog: per-step wall-time tracking + outlier flagging.

At pod scale a single slow host (thermals, faulty ICI link, background
daemon) stretches every synchronous step.  The watchdog keeps a rolling
window of step times, flags steps above `threshold` x the rolling median as
straggler events, and exposes them for the launcher to act on (alert /
eject-and-rejoin in a real deployment; recorded + surfaced here)."""
from __future__ import annotations

import dataclasses
import time
from collections import deque


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float


class StepWatchdog:
    def __init__(self, window: int = 50, threshold: float = 2.5) -> None:
        self.window: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> float:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        if len(self.window) >= 10:
            med = sorted(self.window)[len(self.window) // 2]
            if dt > self.threshold * med:
                self.events.append(StragglerEvent(self._step, dt, med))
        self.window.append(dt)
        self._t0 = None
        return dt

    def observe(self, step: int, duration_s: float) -> None:
        """Record an externally-timed step (e.g. replayed from logs)."""
        self._step = step
        if len(self.window) >= 10:
            med = sorted(self.window)[len(self.window) // 2]
            if duration_s > self.threshold * med:
                self.events.append(StragglerEvent(step, duration_s, med))
        self.window.append(duration_s)
