"""Dolly-like request traces (§7.1 workloads).

The paper replays creative-writing and general-qa requests from the Dolly
dataset.  We model the two categories by their published character: creative
writing has long, high-variance outputs (decode-dominated, strong RLP decay);
general-qa has shorter outputs.  Lengths are lognormal, deterministic per
seed, clipped to sane ranges.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    input_len: int
    output_len: int


# (median input, sigma_in, median output, sigma_out, max_out)
_PROFILES = {
    "creative-writing": (64, 0.6, 320, 0.7, 2048),
    "general-qa": (96, 0.6, 80, 0.6, 512),
}


def generate_trace(task: str, n_requests: int, seed: int = 0) -> list[Request]:
    med_in, sig_in, med_out, sig_out, max_out = _PROFILES[task]
    rng = np.random.default_rng(seed)
    in_lens = np.clip(
        rng.lognormal(np.log(med_in), sig_in, n_requests).astype(int), 4, 2048
    )
    out_lens = np.clip(
        rng.lognormal(np.log(med_out), sig_out, n_requests).astype(int), 4, max_out
    )
    return [Request(i, int(a), int(b)) for i, (a, b) in
            enumerate(zip(in_lens, out_lens))]
