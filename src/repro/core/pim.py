"""Analytical models of the paper's PIM hardware (PAPI §6, Figs. 4/7).

These models reproduce the paper's design-space numbers (energy breakdown,
power-vs-reuse curves, area constraint, FC latency across parallelism) and
power the end-to-end system simulators in `core.system`.  They are the
*reproduction* substrate; the TPU runtime does not pretend to have PIM banks
(see DESIGN.md §2).

Constant derivation (documented, then validated in tests/benchmarks):

* FPU: HBM-PIM-style 16-lane fp16 SIMD MAC @ 666 MHz
    -> 666e6 * 16 * 2 = 21.3 GFLOP/s per FPU.
* Bank: 20.8 GB/s streaming row bandwidth.  1P1B therefore balances at
    21.3 GFLOP/s / 20.8 GB/s ~= 1 FLOP/byte — "matches the arithmetic
    intensity of the attention kernel with speculation length 1" (§6.2).
* Area (Eq. 3/4, CACTI-3DD @22nm): A_bank = 0.83 mm^2, A_FPU = 0.1025 mm^2,
    A_die <= 121 mm^2 -> 128 banks/die for 1P1B & 1P2B, 96 banks/die for
    4P1B (=> FC-PIM capacity 12 GB vs 16 GB, as the paper states).
* Energy: per 2 flops at reuse r, the FC kernel consumes
      DRAM access:  (2/r) bytes  -> amortizes with reuse
      transfer:     (2/r) bytes  -> row-buffer activations broadcast once
      compute:      2 flops      -> constant
  Fitting the two reported fractions (DRAM = 96.7% at r=1, 33.1% at r=64,
  Fig. 7a/b) pins  e_transfer + e_compute jointly; the absolute scale
  e_dram = 0.78 pJ/bit is chosen so 4P1B at reuse>=4 lands exactly at the
  116 W HBM power budget (Fig. 7c).  Solving the 2x2 system:
      e_dram = 0.78 pJ/bit, e_compute = 0.197 pJ/flop,
      e_transfer = 0.00203 pJ/bit.
  All of Fig. 7's qualitative claims then reproduce: 1P1B exceeds budget at
  r=1 (141 W), 1P2B fits (70 W), 4P1B fits iff r >= 4 (115.2 W at r=4).
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Hardware constants
# ---------------------------------------------------------------------------

# Throughput: one FPU = one fp16 MAC / cycle @ 666 MHz (scalar near-bank
# multiplier).  Lane width per FPU is the one microarchitectural parameter
# the paper never states; scalar is required by Fig. 12's claim that
# attention runs 1.7x slower on 1P2B Attn-PIM than 1P1B AttAcc (attention
# must be FPU-throughput-limited on PIM — wide-SIMD FPUs would make it
# bandwidth-limited and FPU-count-independent).  FC-PIM's lane width is fit
# against the paper's headline speedups (see DESIGN.md §Repro-assumptions);
# the resulting FC-PIM : AttAcc FC throughput ratio of ~3x independently
# reproduces Fig. 12's reported 2.9x FC speedup.
FPU_FLOPS = 666e6 * 2               # 1.33 GFLOP/s per scalar FPU
FCPIM_FPU_LANES = 2                 # fitted (see above)
BANK_BW = 20.8e9                    # bytes/s per bank (row streaming)

# AttAcc's near-bank units buffer a bounded window of activation rows: its
# FC path reuses a fetched DRAM row across at most this many activation rows
# (PAPI §6.1 presents *unbounded* batch-level reuse as the new capability
# that makes 4P1B feasible).  Fit jointly with the GPU constants below
# against the paper's headline speedups; Figs. 4/10/11/12 act as held-out
# validation.
ATTACC_FC_REUSE_CAP = 1             # fitted: no batch-level reuse at all

# Effective fraction of peak HBM bandwidth a real A100 sustains on skinny
# (GEMV-like) kernels — published A100 GEMV measurements land at 50-70%.
GPU_MEMBW_EFF = 0.7
DIES_PER_STACK = 8                  # 8-high HBM3
A_BANK_MM2 = 0.83
A_FPU_MM2 = 0.1025
A_DIE_MM2 = 121.0
HBM_POWER_BUDGET_W = 116.0          # per 8-high 16GB HBM3 cube (IDD7)
BANK_CAPACITY_GB = 16.0 / 1024      # 16 GB per stack of 128 banks x 8 dies

# Energy model (fit to Fig. 7a/b two-point system; derivation above).
E_DRAM_PJ_PER_BIT = 0.78
E_TRANSFER_PJ_PER_BIT = 0.00203     # amortizing component (scales 1/reuse)
E_COMPUTE_PJ_PER_FLOP = 0.197       # constant component

# A100 GPU (paper §3.1 / §7.1)
GPU_PEAK_FLOPS = 312e12             # fp16 tensor core
GPU_HBM_BW = 1935e9                 # bytes/s
GPU_POWER_W = 400.0
GPU_KERNEL_OVERHEAD_S = 5e-6        # per-kernel launch latency
# GPU energy: dynamic energy split so that a roofline-balanced kernel at
# full utilization draws ~GPU_POWER_W.
E_GPU_PJ_PER_FLOP = 0.8
E_GPU_HBM_PJ_PER_BYTE = 60.0

# Interconnects (§6.3)
NVLINK_BW = 600e9                   # PU <-> FC-PIM
PCIE_BW = 64e9                      # PU <-> Attn-PIM (PCIe 5.0 x16-ish)
LINK_LATENCY_S = 2e-6

# Host -> PIM command/dispatch overhead per offloaded kernel (the host CPU
# issues bank-level command streams; AttAcc reports tens of us per kernel).
PIM_KERNEL_OVERHEAD_S = 15e-6


def max_banks_per_die(fpus_per_bank: float) -> int:
    """Eq. 3: m (n*A_FPU + A_bank) <= A_Max, rounded down to a multiple of 32
    (bank-group granularity)."""
    m = int(A_DIE_MM2 / (fpus_per_bank * A_FPU_MM2 + A_BANK_MM2))
    return (m // 32) * 32


@dataclasses.dataclass(frozen=True)
class PIMDeviceConfig:
    """One PIM-enabled HBM stack in an xPyB configuration."""
    name: str
    fpus_per_bank: float            # x / y  (4P1B -> 4.0, 1P2B -> 0.5)
    banks_per_die: int
    fpu_lanes: int = 1              # MAC lanes per FPU (scalar by default)

    @property
    def banks(self) -> int:
        return self.banks_per_die * DIES_PER_STACK

    @property
    def fpus(self) -> int:
        return int(self.banks * self.fpus_per_bank)

    @property
    def peak_flops(self) -> float:
        return self.fpus * FPU_FLOPS * self.fpu_lanes

    @property
    def internal_bw(self) -> float:
        return self.banks * BANK_BW

    @property
    def capacity_bytes(self) -> float:
        return self.banks * BANK_CAPACITY_GB * 1e9

    def area_per_die_mm2(self) -> float:
        return self.banks_per_die * (
            self.fpus_per_bank * A_FPU_MM2 + A_BANK_MM2
        )

    # -- power / energy ------------------------------------------------------
    def power_at(self, reuse: float, utilization: float = 1.0) -> float:
        """Sustained power (W) of the *design point* (Fig. 7c): banks stream
        DRAM rows at full bandwidth, each streamed element feeding
        `fpus_per_bank * reuse` MACs.  Per 2 flops: 2/reuse bytes of DRAM
        access + 2/reuse bytes of transfer + 2 flops of compute.

        Note this is the bandwidth-driven energy-accounting rate the paper's
        power figures use (MACs keeping pace with the row stream), distinct
        from the scalar-FPU latency rate `peak_flops` — see module docstring
        and DESIGN.md §Repro-assumptions.
        """
        flops_rate = self.banks * self.fpus_per_bank * BANK_BW * utilization
        amortized_bytes_rate = flops_rate / reuse            # (2/r per 2 flops)
        p = (
            amortized_bytes_rate * 8 * E_DRAM_PJ_PER_BIT
            + amortized_bytes_rate * 8 * E_TRANSFER_PJ_PER_BIT
            + flops_rate * E_COMPUTE_PJ_PER_FLOP
        ) * 1e-12
        return p

    def sustainable_utilization(self, reuse: float) -> float:
        """Fraction of peak FLOP/s sustainable under the HBM power budget —
        the paper's power-throttling constraint on dense PIM configs."""
        p1 = self.power_at(reuse, 1.0)
        return min(1.0, HBM_POWER_BUDGET_W / p1)

    # -- kernel latency ------------------------------------------------------
    def gemv_time(self, m: int, h: int, h_out: int,
                  bytes_per_el: int = 2) -> float:
        """FC kernel (m x h) @ (h x h_out) on ONE device, weights resident.

        reuse level == m (each weight row read once, used for m activations).
        """
        flops = 2.0 * m * h * h_out
        weight_bytes = h * h_out * bytes_per_el
        reuse = max(float(m), 1.0)
        util = self.sustainable_utilization(reuse)
        t_compute = flops / (self.peak_flops * util)
        t_memory = weight_bytes / self.internal_bw
        return max(t_compute, t_memory)

    def attention_time(self, tlp: int, ctx: int, n_kv: int, n_q: int,
                       head_dim: int, bytes_per_el: int = 2) -> float:
        """Decode attention for ONE request on ONE device: TLP query tokens
        against a ctx-long KV cache (GQA: n_q query heads share n_kv KV
        heads).  No cross-request reuse => reuse level == TLP * group."""
        group = max(n_q // max(n_kv, 1), 1)
        kv_bytes = 2.0 * ctx * n_kv * head_dim * bytes_per_el
        flops = 4.0 * tlp * ctx * n_q * head_dim
        reuse = max(float(tlp * group), 1.0)
        util = self.sustainable_utilization(reuse)
        t_compute = flops / (self.peak_flops * util)
        t_memory = kv_bytes / self.internal_bw
        return max(t_compute, t_memory)

    # -- kernel energy -------------------------------------------------------
    def kernel_energy(self, flops: float, dram_bytes: float,
                      act_bytes: float) -> float:
        return (
            dram_bytes * 8 * E_DRAM_PJ_PER_BIT
            + act_bytes * 8 * E_TRANSFER_PJ_PER_BIT
            + flops * E_COMPUTE_PJ_PER_FLOP
        ) * 1e-12


# The three PIM flavors evaluated in the paper.
ATTACC = PIMDeviceConfig("attacc-1p1b", 1.0, max_banks_per_die(1.0))
HBM_PIM = PIMDeviceConfig("hbmpim-1p2b", 0.5, max_banks_per_die(0.5))
FC_PIM = PIMDeviceConfig("fcpim-4p1b", 4.0, max_banks_per_die(4.0),
                         fpu_lanes=FCPIM_FPU_LANES)
ATTN_PIM = PIMDeviceConfig("attnpim-1p2b", 0.5, max_banks_per_die(0.5))


def energy_breakdown(reuse: float) -> dict[str, float]:
    """Fractions of PIM energy for the FC kernel at a given data-reuse level
    (Fig. 7a/b).  Per 2 flops: 2/reuse weight bytes from DRAM, 2/reuse
    activation transfer bytes, 2 flops of compute."""
    dram = (2.0 / reuse) * 8 * E_DRAM_PJ_PER_BIT
    transfer = (2.0 / reuse) * 8 * E_TRANSFER_PJ_PER_BIT
    compute = 2.0 * E_COMPUTE_PJ_PER_FLOP
    total = dram + transfer + compute
    return {
        "dram": dram / total,
        "transfer": transfer / total,
        "compute": compute / total,
    }


def gpu_fc_time(m: int, h: int, h_out: int, n_gpus: int = 6,
                bytes_per_el: int = 2) -> float:
    """FC kernel on the GPU pool (tensor-parallel over n_gpus)."""
    flops = 2.0 * m * h * h_out
    byts = (h * h_out + m * (h + h_out)) * bytes_per_el
    t = max(flops / (GPU_PEAK_FLOPS * n_gpus),
            byts / (GPU_HBM_BW * GPU_MEMBW_EFF * n_gpus))
    return t + GPU_KERNEL_OVERHEAD_S


def gpu_attention_time(rlp: int, tlp: int, ctx: int, n_kv: int, n_q: int,
                       head_dim: int, n_gpus: int = 6,
                       bytes_per_el: int = 2) -> float:
    kv_bytes = 2.0 * ctx * n_kv * head_dim * bytes_per_el * rlp
    flops = 4.0 * tlp * ctx * n_q * head_dim * rlp
    t = max(flops / (GPU_PEAK_FLOPS * n_gpus),
            kv_bytes / (GPU_HBM_BW * GPU_MEMBW_EFF * n_gpus))
    return t + GPU_KERNEL_OVERHEAD_S


def gpu_kernel_energy(flops: float, hbm_bytes: float) -> float:
    return (flops * E_GPU_PJ_PER_FLOP + hbm_bytes * E_GPU_HBM_PJ_PER_BYTE) * 1e-12
