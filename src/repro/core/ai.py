"""Arithmetic-intensity estimation (PAPI §5.1, Eq. 1 / Eq. 2).

The FC kernel with weight matrix (h, h_out) and input (m, h), m = RLP*TLP:

    AI = #Flops / #Bytes
       = (m * h * h_out * 2) / ((m*h + m*h_out + h*h_out) * bytes_per_el)

For the paper's square case (h_out = h) and fp16 this is Eq. 1:

    AI = (m * h^2 * 2) / ((2*m*h + h^2) * 2)

and in the large-h limit AI -> m = RLP * TLP (Eq. 2) — the O(1) online
estimate the scheduler uses.  `ai_error` quantifies the Eq.1-vs-Eq.2 gap
(Fig. 6; largest for small-h archs like qwen2-0.5b).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def fc_ai_exact(m: int, h: int, h_out: int | None = None,
                bytes_per_el: int = 2) -> float:
    """Eq. 1 (generalized to rectangular FC weights)."""
    if h_out is None:
        h_out = h
    flops = 2.0 * m * h * h_out
    byts = (m * h + m * h_out + h * h_out) * bytes_per_el
    return flops / byts


def fc_ai_estimate(rlp: int, tlp: int) -> float:
    """Eq. 2: AI ~= RLP * TLP."""
    return float(rlp * tlp)


def ai_error(m: int, h: int) -> float:
    """Relative error of Eq. 2 vs Eq. 1 for the paper's square FC."""
    exact = fc_ai_exact(m, h)
    return abs(fc_ai_estimate(m, 1) * 1.0 - exact) / exact


def effective_parallelism(cfg: ModelConfig, rlp: int, tlp: int) -> float:
    """Decoding parallelism as seen by the *FC weights* of this arch.

    Dense FC: every token touches every weight -> m = RLP*TLP.
    MoE expert FC (paper §6.5): each expert sees only its routed share, so
    per-expert parallelism is RLP*TLP*top_k/E — experts stay memory-bound
    far longer.  This is PAPI's MoE observation made quantitative.
    """
    m = float(rlp * tlp)
    if cfg.moe is not None and cfg.moe.num_experts:
        return m * cfg.moe.top_k / cfg.moe.num_experts
    return m


def attention_ai(tlp: int, bytes_per_el: int = 2) -> float:
    """Attention AI per KV byte: ~2*TLP flops per KV element read.

    Independent of RLP (no cross-request KV reuse) — the reason attention is
    always memory-bound and lives on Attn-PIM.
    """
    return 2.0 * tlp / bytes_per_el
