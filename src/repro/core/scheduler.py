"""PAPI's dynamic parallelism-aware scheduler (§4.1, §5.2).

The paper's mechanism, faithfully:

* a `TLP register` updated by the host when the speculation length changes;
* RLP tracked by counting <|eos|> tokens in the gathered output vector after
  every decoding iteration (token-level scheduling, §5.2.2) and bumped when
  continuous batching admits new requests;
* the O(1) arithmetic-intensity estimate AI ~= RLP * TLP (Eq. 2), corrected
  for MoE expert sparsity per §6.5 (see `core.ai.effective_parallelism`);
* a calibrated memory-boundedness threshold alpha: AI > alpha => the FC
  kernel is compute-bound => run it on the PUs (MXU path); otherwise run it
  on FC-PIM (the weight-streaming fc_gemv path).  Attention is *always*
  memory-bound and pinned to Attn-PIM (the flash-decode kernel next to the
  KV shard).

The decision is host-side and O(batch) per iteration; both kernel variants
are pre-compiled, so a reschedule costs nothing but the dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.ai import effective_parallelism

FC_PU = "pu"        # compute-bound  -> high-performance processor (MXU)
FC_PIM = "pim"      # memory-bound   -> FC-PIM (weight-streaming kernel)
ATTN_PIM = "attn_pim"


@dataclasses.dataclass
class SchedulerEvent:
    iteration: int
    rlp: int
    tlp: int
    ai_estimate: float
    assignment: str
    rescheduled: bool
    # the threshold the estimate was compared against — recorded per event
    # so a trace shows the decision inputs, not just the verdict (and stays
    # meaningful once alpha becomes a measured, time-varying quantity)
    alpha: float = 0.0


@dataclasses.dataclass
class PapiScheduler:
    """Online kernel-to-hardware scheduler."""
    cfg: ModelConfig
    alpha: float                       # memory-boundedness threshold
    tlp: int = 1                       # the TLP register (§5.2.2)
    rlp: int = 0
    iteration: int = 0
    eos_token: int = 2

    def __post_init__(self) -> None:
        self._assignment = self._decide()
        self.events: list[SchedulerEvent] = []
        self.num_reschedules = 0

    # -- §5.2.1 initial scheduling -------------------------------------------
    def initial_schedule(self, batch_size: int, spec_len: int) -> str:
        self.rlp = batch_size
        self.tlp = spec_len
        self.iteration = 0
        self._assignment = self._decide()
        self._log(rescheduled=False)
        return self._assignment

    # -- §5.2.2 runtime scheduling -------------------------------------------
    def set_tlp(self, tlp: int) -> None:
        """Host CPU writes the TLP register.  A TLP change is a monitored
        parallelism change (§5.2.2), so the identification step runs
        immediately."""
        self.tlp = int(tlp)
        new = self._decide()
        if new != self._assignment:
            self.num_reschedules += 1
            self._assignment = new
            self._log(rescheduled=True)

    def observe_outputs(self, output_tokens: Sequence[int],
                        admitted: int = 0) -> str:
        """After each decoding iteration: gather the batch's new tokens,
        count <|eos|> occurrences (step 1-2 of §5.2.2), fold in any newly
        admitted requests (mixed continuous batching), re-estimate AI and
        reschedule if the boundedness class flipped (steps 3-4)."""
        finished = sum(1 for t in output_tokens if t == self.eos_token)
        return self.observe_counts(finished, admitted)

    def observe_counts(self, finished, admitted: int = 0) -> str:
        """`finished` may be a plain int, a numpy scalar, or an array of
        per-slot finish counts/flags (the fused engine hands the device
        bundle straight over) — arrays are summed here."""
        finished = int(np.sum(finished))
        admitted = int(np.sum(admitted))
        self.iteration += 1
        self.rlp = max(self.rlp - finished + admitted, 0)
        new = self._decide()
        rescheduled = new != self._assignment
        if rescheduled:
            self.num_reschedules += 1
        self._assignment = new
        self._log(rescheduled)
        return new

    # -- decision --------------------------------------------------------------
    @property
    def ai_estimate(self) -> float:
        return effective_parallelism(self.cfg, self.rlp, self.tlp)

    def _decide(self) -> str:
        return FC_PU if self.ai_estimate > self.alpha else FC_PIM

    @property
    def fc_assignment(self) -> str:
        return self._assignment

    @property
    def attention_assignment(self) -> str:
        # Attention is always memory-bound (§4.1): pinned to Attn-PIM.
        return ATTN_PIM

    def _log(self, rescheduled: bool) -> None:
        self.events.append(SchedulerEvent(
            iteration=self.iteration, rlp=self.rlp, tlp=self.tlp,
            ai_estimate=self.ai_estimate, assignment=self._assignment,
            rescheduled=rescheduled, alpha=self.alpha,
        ))
