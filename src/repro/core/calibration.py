"""Offline alpha calibration (§5.2.1).

"The threshold alpha is determined through offline iterative evaluation,
where we run the FC kernel on both PIM and PU units under varying
parallelization levels, using the observed execution times to establish the
best alpha."

Two calibrators:

* `calibrate_alpha_model` — runs the *analytical* device models (core.pim)
  over an RLP*TLP grid; used by the system simulators that reproduce the
  paper's figures.
* `calibrate_alpha_measured` — times two real callables (the MXU dot vs the
  fc_gemv Pallas path) on the actual backend; used by the serving engine.
  On a TPU deployment this is run once at startup per model.
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import pim


def _crossover_alpha(ms: Sequence[int], t_pim: Sequence[float],
                     t_pu: Sequence[float]) -> float:
    """Pick alpha minimizing total misassignment cost over the grid: for a
    threshold a, kernels with m > a run on PU, else PIM."""
    ms = list(ms)
    candidates = [0.5] + [m + 0.5 for m in ms]
    best_a, best_cost = candidates[0], float("inf")
    for a in candidates:
        cost = sum(
            (t_pu[i] if m > a else t_pim[i]) for i, m in enumerate(ms)
        )
        if cost < best_cost:
            best_cost, best_a = cost, a
    return best_a


def calibrate_alpha_model(
    cfg: ModelConfig,
    n_fc_devices: int = 30,
    n_gpus: int = 6,
    ms: Sequence[int] | None = None,
) -> float:
    """Analytical calibration: FC (m, h) @ (h, h) on FC-PIM vs the GPU pool."""
    h = cfg.d_model
    if ms is None:
        ms = [1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512]
    t_pim = [
        pim.FC_PIM.gemv_time(m, h, max(h // n_fc_devices, 1)) for m in ms
    ]
    t_pu = [pim.gpu_fc_time(m, h, h, n_gpus=n_gpus) for m in ms]
    return _crossover_alpha(ms, t_pim, t_pu)


def calibrate_alpha_measured(
    run_pu: Callable[[int], None],
    run_pim: Callable[[int], None],
    ms: Sequence[int] | None = None,
    repeats: int = 5,
) -> float:
    """Wall-clock calibration of the two real FC paths.

    `run_pu(m)` / `run_pim(m)` execute (and block on) one FC kernel with m
    activation rows.  Returns the crossover threshold.
    """
    if ms is None:
        ms = [1, 2, 4, 8, 16, 32, 64, 128]

    def bench(fn: Callable[[int], None], m: int) -> float:
        fn(m)  # warmup / compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(m)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    t_pu = [bench(run_pu, m) for m in ms]
    t_pim = [bench(run_pim, m) for m in ms]
    return _crossover_alpha(ms, t_pim, t_pu)
