"""End-to-end decode simulators for the paper's four systems (§7).

Systems (device inventory identical across systems: 90 HBM stacks — 30
holding FC weights, 60 holding KV caches — plus, where applicable, the
compute of 6 A100 GPUs):

  a100_attacc   — FC always on GPUs; attention on AttAcc (1P1B)   [baseline]
  a100_hbmpim   — FC always on GPUs; attention on HBM-PIM (1P2B)
  attacc_only   — FC *and* attention on AttAcc PIM (no GPU compute)
  papi          — FC dynamically on GPUs or FC-PIM (4P1B) via the online
                  scheduler; attention on Attn-PIM (1P2B)
  pim_only_papi — FC always on FC-PIM; attention on Attn-PIM (§7.4 ablation)

The simulation replays a Dolly-like trace with static batching: RLP decays
as requests finish (Fig. 3), context lengths grow per decode iteration, and
PAPI's scheduler re-evaluates AI = RLP*TLP against alpha each iteration.

Latency/energy per kernel come from `core.pim`'s calibrated device models.
AttAcc's FC path has no batch-level data reuse (that capability *is* the
FC-PIM contribution), so its FC cost scales with m in both time and DRAM
energy; FC-PIM fetches each weight row once per iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.configs.base import ModelConfig
from repro.core import pim
from repro.core.calibration import calibrate_alpha_model
from repro.core.scheduler import FC_PIM, FC_PU, PapiScheduler
from repro.core.traces import Request

N_FC_DEVICES = 30
N_ATTN_DEVICES = 60
N_GPUS = 6
E_LINK_PJ_PER_BYTE = 10.0


@dataclasses.dataclass
class FCDims:
    """Per-layer FC kernels as (h_in, h_out) pairs."""
    kernels: list[tuple[int, int]]

    @classmethod
    def from_config(cls, cfg: ModelConfig) -> "FCDims":
        h, hd = cfg.d_model, cfg.resolved_head_dim
        ks = [
            (h, cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd),  # QKV
            (cfg.num_heads * hd, h),                              # out proj
        ]
        if cfg.moe is not None and cfg.moe.num_experts:
            f = cfg.moe.d_ff
            # active expert FCs per token: top_k experts
            ks += [(h, 3 * f * cfg.moe.top_k // 1)]
            ks += [(f * cfg.moe.top_k, h)]
        elif cfg.mlp == "swiglu":
            ks += [(h, 2 * cfg.d_ff), (cfg.d_ff, h)]
        else:
            ks += [(h, cfg.d_ff), (cfg.d_ff, h)]
        return cls(ks)

    def flops(self, m: int) -> float:
        return sum(2.0 * m * a * b for a, b in self.kernels)

    def weight_bytes(self, bytes_per_el: int = 2) -> float:
        return sum(a * b * bytes_per_el for a, b in self.kernels)


@dataclasses.dataclass
class SimResult:
    time_s: float
    energy_j: float
    tokens: int
    iterations: int
    fc_time_s: float = 0.0
    attn_time_s: float = 0.0
    comm_time_s: float = 0.0
    reschedules: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / max(self.time_s, 1e-12)

    @property
    def energy_per_token(self) -> float:
        return self.energy_j / max(self.tokens, 1)


# ---------------------------------------------------------------------------
# Per-iteration kernel costs
# ---------------------------------------------------------------------------

def _fc_iter_cost(system: str, assignment: str, cfg: ModelConfig, m: int):
    """(time, energy) for ALL FC kernels of one decode iteration."""
    fc = FCDims.from_config(cfg)
    n_layers = cfg.num_layers
    flops = fc.flops(m) * n_layers
    wbytes = fc.weight_bytes() * n_layers
    act_bytes = sum(m * (a + b) * 2 for a, b in fc.kernels) * n_layers

    if assignment == FC_PU:
        t = sum(pim.gpu_fc_time(m, a, b, N_GPUS) for a, b in fc.kernels)
        t *= n_layers
        e = pim.gpu_kernel_energy(flops, wbytes + act_bytes)
        return t, e

    if system in ("papi", "pim_only_papi"):
        dev = pim.FC_PIM
        reuse = max(float(m), 1.0)
        dram_bytes = wbytes          # fetched once, reused across m rows
    else:                            # attacc_only: bounded batch-level reuse
        dev = pim.ATTACC
        cap = pim.ATTACC_FC_REUSE_CAP
        reuse = float(min(max(m, 1), cap))
        dram_bytes = wbytes * -(-m // cap)   # re-streamed per reuse window
    util = dev.sustainable_utilization(reuse)
    t_compute = flops / (dev.peak_flops * N_FC_DEVICES * util)
    t_memory = dram_bytes / (dev.internal_bw * N_FC_DEVICES)
    # host dispatch: one command stream per FC kernel per layer (§5.2)
    t_dispatch = n_layers * len(fc.kernels) * pim.PIM_KERNEL_OVERHEAD_S
    t = max(t_compute, t_memory) + t_dispatch
    e = dev.kernel_energy(flops, dram_bytes, act_bytes)
    return t, e


def _attn_iter_cost(system: str, cfg: ModelConfig, tlp: int,
                    ctxs: Sequence[int]):
    """(time, energy) for attention of one decode iteration over the active
    requests' context lengths."""
    n_layers = cfg.num_attention_applications()
    if n_layers == 0 or not ctxs:
        return 0.0, 0.0
    nkv, nq, hd = cfg.num_kv_heads, cfg.num_heads, cfg.resolved_head_dim
    kv_bytes = sum(2.0 * c * nkv * hd * 2 for c in ctxs) * n_layers
    flops = sum(4.0 * tlp * c * nq * hd for c in ctxs) * n_layers

    if system == "a100_hbmpim":
        dev = pim.HBM_PIM
    elif system in ("papi", "pim_only_papi"):
        dev = pim.ATTN_PIM
    else:
        dev = pim.ATTACC
    group = max(nq // max(nkv, 1), 1)
    util = dev.sustainable_utilization(max(float(tlp * group), 1.0))
    t_compute = flops / (dev.peak_flops * N_ATTN_DEVICES * util)
    t_memory = kv_bytes / (dev.internal_bw * N_ATTN_DEVICES)
    t = max(t_compute, t_memory) + n_layers * pim.LINK_LATENCY_S
    e = dev.kernel_energy(flops, kv_bytes, 0.0)
    return t, e


def _comm_iter_cost(system: str, cfg: ModelConfig, m: int, rlp: int,
                    fc_assignment: str):
    """Inter-device traffic per iteration: Q vectors + attention outputs
    cross PU <-> Attn-PIM (PCIe/CXL); activations cross PU <-> FC-PIM
    (NVLink) when FC runs on PIM."""
    h = cfg.d_model
    n_attn = cfg.num_attention_applications()
    # per attention layer: q out + attn result back, per active token
    attn_traffic = 2.0 * m * h * 2 * n_attn
    t = attn_traffic / pim.PCIE_BW + 2 * n_attn * pim.LINK_LATENCY_S
    e = attn_traffic * E_LINK_PJ_PER_BYTE * 1e-12
    if fc_assignment == FC_PIM:
        # weights are 2D-block distributed over N_FC_DEVICES (§6.4): the
        # activation broadcasts to every device holding a block row, and the
        # row-partitioned partial sums reduce back — 2x broadcast + 2x
        # tree-reduce traffic per layer boundary.
        fc_traffic = 4.0 * 2.0 * m * h * 2 * cfg.num_layers
        bw = pim.NVLINK_BW if system in ("papi", "pim_only_papi") else pim.PCIE_BW
        t += fc_traffic / bw + 2 * cfg.num_layers * pim.LINK_LATENCY_S
        e += fc_traffic * E_LINK_PJ_PER_BYTE * 1e-12
    return t, e


# ---------------------------------------------------------------------------
# Decode-phase simulation
# ---------------------------------------------------------------------------

def calibrate_alpha_system(cfg: ModelConfig,
                           ms: Sequence[int] | None = None) -> float:
    """Offline alpha calibration against the *full* per-iteration cost the
    system observes (kernel + dispatch + interconnect), per §5.2.1: 'using
    the observed execution times to establish the best alpha'."""
    if ms is None:
        ms = [1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512]

    def iter_cost(assignment: str, m: int) -> float:
        t_fc, _ = _fc_iter_cost("papi", assignment, cfg, m)
        t_cm, _ = _comm_iter_cost("papi", cfg, m, m, assignment)
        return t_fc + t_cm

    candidates = [0.5] + [m + 0.5 for m in ms]
    best_a, best_cost = candidates[0], float("inf")
    for a in candidates:
        cost = sum(
            iter_cost(FC_PU if m > a else FC_PIM, m) for m in ms
        )
        if cost < best_cost:
            best_cost, best_a = cost, a
    return best_a


def simulate_decode(
    system: str,
    cfg: ModelConfig,
    requests: Sequence[Request],
    batch_size: int,
    spec_len: int,
    alpha: float | None = None,
) -> SimResult:
    """Static batching (§7.1): batches of `batch_size` run to completion;
    RLP decays within each batch as requests finish."""
    if alpha is None:
        alpha = calibrate_alpha_system(cfg)
    sched = PapiScheduler(cfg, alpha=alpha, tlp=spec_len)

    total = SimResult(0.0, 0.0, 0, 0)
    for start in range(0, len(requests), batch_size):
        batch = list(requests[start : start + batch_size])
        sched.initial_schedule(len(batch), spec_len)
        remaining = {r.req_id: r.output_len for r in batch}
        ctx = {r.req_id: r.input_len for r in batch}

        while remaining:
            rlp = len(remaining)
            tlp = spec_len
            m = rlp * tlp

            if system == "papi":
                assignment = sched.fc_assignment
            elif system in ("a100_attacc", "a100_hbmpim"):
                assignment = FC_PU
            else:
                assignment = FC_PIM

            t_fc, e_fc = _fc_iter_cost(system, assignment, cfg, m)
            t_at, e_at = _attn_iter_cost(system, cfg, tlp, list(ctx[i] for i in remaining))
            t_cm, e_cm = _comm_iter_cost(system, cfg, m, rlp, assignment)

            total.time_s += t_fc + t_at + t_cm
            total.fc_time_s += t_fc
            total.attn_time_s += t_at
            total.comm_time_s += t_cm
            total.energy_j += e_fc + e_at + e_cm
            total.iterations += 1

            finished = 0
            for rid in list(remaining):
                remaining[rid] -= tlp
                ctx[rid] += tlp
                total.tokens += min(tlp, remaining[rid] + tlp)
                if remaining[rid] <= 0:
                    del remaining[rid]
                    finished += 1
            sched.observe_counts(finished)
        total.reschedules = sched.num_reschedules
    return total


def simulate_prefill_gpu(cfg: ModelConfig, requests: Sequence[Request]) -> float:
    """Prefill is compute-bound and runs on the GPU pool in every system
    (§7.4).  Returns time only (identical across systems)."""
    fc = FCDims.from_config(cfg)
    t = 0.0
    for r in requests:
        flops = fc.flops(r.input_len) * cfg.num_layers
        # attention flops (quadratic, small at these input lengths)
        flops += (4.0 * r.input_len ** 2 * cfg.num_heads * cfg.resolved_head_dim
                  * cfg.num_attention_applications())
        t += flops / (pim.GPU_PEAK_FLOPS * N_GPUS)
    return t


SYSTEMS = ("a100_attacc", "a100_hbmpim", "attacc_only", "papi", "pim_only_papi")


def compare_systems(
    cfg: ModelConfig,
    requests: Sequence[Request],
    batch_size: int,
    spec_len: int,
    systems: Sequence[str] = SYSTEMS,
) -> dict[str, SimResult]:
    return {
        s: simulate_decode(s, cfg, requests, batch_size, spec_len)
        for s in systems
    }
