"""The paper's primary contribution: PAPI's online arithmetic-intensity
estimation, dynamic parallelism-aware scheduling, hybrid-PIM device models,
and the end-to-end system simulators that reproduce its evaluation."""
from repro.core.ai import (
    attention_ai,
    effective_parallelism,
    fc_ai_estimate,
    fc_ai_exact,
)
from repro.core.calibration import (
    calibrate_alpha_measured,
    calibrate_alpha_model,
)
from repro.core.scheduler import ATTN_PIM, FC_PIM, FC_PU, PapiScheduler
from repro.core.system import (
    SYSTEMS,
    SimResult,
    calibrate_alpha_system,
    compare_systems,
    simulate_decode,
    simulate_prefill_gpu,
)
from repro.core.traces import Request, generate_trace

__all__ = [
    "ATTN_PIM", "FC_PIM", "FC_PU", "SYSTEMS",
    "PapiScheduler", "Request", "SimResult",
    "attention_ai", "calibrate_alpha_measured", "calibrate_alpha_model",
    "calibrate_alpha_system", "compare_systems", "effective_parallelism",
    "fc_ai_estimate", "fc_ai_exact", "generate_trace", "simulate_decode",
    "simulate_prefill_gpu",
]
