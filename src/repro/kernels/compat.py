"""Version-portability helpers for the Pallas TPU kernels.

`pltpu.TPUCompilerParams` was renamed `pltpu.CompilerParams` across jax
releases; the kernels target both so the repo runs on whatever toolchain the
host bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Construct compiler params under either pltpu API name."""
    return _CompilerParams(**kwargs)
