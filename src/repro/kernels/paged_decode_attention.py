"""Paged flash-decode GQA attention — Attn-PIM over bank-row pages.

The dense kernel (`kernels/decode_attention.py`) streams a per-slot
contiguous KV slab.  Under the paged KV cache the physical layout is a pool
of fixed-size pages (`[num_pages, page_size, nkv, hd]` — one page per
Attn-PIM bank row, see `serving/kv_pages.py`) and a per-request block table
maps logical KV blocks to physical pages.  This kernel runs the SAME online
softmax over that layout:

  grid = (batch, kv_heads, max_blocks)    last axis innermost/sequential
  scalar prefetch:  lens   [b]            per-request valid lengths
                    tables [b, max_blocks] logical block -> physical page

The K/V `index_map` resolves `tables[i, kb]` *before* each grid step's DMA
is issued (that is what `PrefetchScalarGridSpec` buys us), so the gather is
free: the pipeline simply fetches block `kb`'s page from wherever it
physically lives.  No `[b, S, ...]` contiguous view is ever materialized.

Ragged block skipping carries over unchanged: for blocks entirely past a
request's length, the logical block index is clamped to the last valid one
(consecutive grid steps then fetch the same physical page, and the Pallas
pipeline elides the redundant DMA) and the kernel body no-ops via
`pl.when`.

Bit-identity with the dense kernel is by construction: the kernel *body* is
literally `decode_attention._kernel` (imported, not copied) with
`block_k = page_size` — on identical KV contents the two kernels execute
the same sequence of per-block operations, so outputs are bit-equal
(asserted in `tests/test_serving_paged.py`).

Query windows (``q_rows > 1``) carry over from the dense kernel unchanged:
the q block holds R = q_rows * g (window, group)-row-major rows per KV
head, `lens` counts ALL q_rows window tokens, and the shared body applies
the intra-window causal mask (row r sees KV position j iff
``j < lens - (q_rows - 1) + r``).  This is what puts speculative verify
windows and chunked-prefill waves on the paged Pallas hot path — the XLA
alternative must first materialize the whole `[b, max_blocks * page, ...]`
pool view via `models.layers.gather_kv_pages`.

Block-table safety contract (any q_rows >= 1): entries at or past a
request's last valid block may point anywhere (the engine points them at
the shared garbage page) — with `block_skip=True` they are clamped away,
and with `block_skip=False` their scores are masked to -inf by `lens`, so
either way they never reach the output.  Window rows extend the contract
forward in time: row r masks everything past its own absolute position, so
table entries covering positions written for LATER rows of the same window
(or garbage beyond the window) never leak backward into row r.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.decode_attention import _kernel


def _paged_kernel(lens_ref, tables_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page_size, num_blocks,
                  block_skip, q_rows=1):
    # tables_ref is consumed exclusively by the index_map (the DMA source
    # address); the arithmetic is the dense kernel's, block_k = page_size.
    del tables_ref
    _kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            block_k=page_size, num_kb=num_blocks, block_skip=block_skip,
            q_rows=q_rows)


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_skip", "q_rows"))
def paged_decode_attention(
    q: jax.Array,          # [b, nkv, R, hd]   R = q_rows * g
    k_pages: jax.Array,    # [num_pages, page_size, nkv, hd]
    v_pages: jax.Array,    # [num_pages, page_size, nkv, hd]
    lens: jax.Array,       # [b] int32 valid lengths (ALL q_rows included)
    tables: jax.Array,     # [b, max_blocks] int32 physical page ids
    *,
    interpret: bool | None = None,
    block_skip: bool = True,
    q_rows: int = 1,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, nkv, g, hd = q.shape
    assert g % q_rows == 0, (g, q_rows)
    page_size = k_pages.shape[1]
    num_blocks = tables.shape[1]
    lens1 = lens.astype(jnp.int32).reshape(b)
    tables1 = tables.astype(jnp.int32).reshape(b, num_blocks)

    def q_index(i, j, kb, lens_ref, tables_ref):
        return (i, j, 0, 0)

    def kv_index(i, j, kb, lens_ref, tables_ref):
        if block_skip:
            # clamp to the request's last valid logical block; repeated
            # physical indices let the pipeline skip the redundant fetch
            last = jnp.maximum(pl.cdiv(lens_ref[i], page_size) - 1, 0)
            kb = jnp.minimum(kb, last)
        return (tables_ref[i, kb], 0, j, 0)

    grid = (b, nkv, num_blocks)
    kernel = functools.partial(_paged_kernel, page_size=page_size,
                               num_blocks=num_blocks, block_skip=block_skip,
                               q_rows=q_rows)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), q_index),
            pl.BlockSpec((1, page_size, 1, hd), kv_index),
            pl.BlockSpec((1, page_size, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), q_index),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="papi_paged_decode_attention",
    )(lens1, tables1, q, k_pages, v_pages)


def paged_decode_attention_sharded(
    q: jax.Array,          # [b, nkv, R, hd]   R = q_rows * g
    k_pages: jax.Array,    # [num_pages, page_size, nkv, hd]
    v_pages: jax.Array,    # [num_pages, page_size, nkv, hd]
    lens: jax.Array,       # [b] int32 (ALL q_rows included)
    tables: jax.Array,     # [b, max_blocks] int32
    *,
    mesh,
    axis: str = "model",
    interpret: bool | None = None,
    block_skip: bool = True,
    q_rows: int = 1,
) -> jax.Array:
    """One Attn-PIM unit per KV-head shard, paged edition (§5.3).

    Identical split to `decode_attention_sharded`: the KV-head dim is the
    axis with no cross-shard reduction, so each shard runs the full paged
    online-softmax pass over its local heads' pages and the result is
    bit-identical to the unsharded kernel — query windows included (the
    window rows ride their head's shard).  Lens and block tables are
    replicated — page ids index the pool dim, which every shard holds in
    full for its own heads.  Indivisible head counts fall back to the
    replicated kernel, matching the dense wrapper.
    """
    nkv = q.shape[1]
    size = dict(mesh.shape).get(axis, 1)
    if size <= 1 or nkv % size != 0:
        return paged_decode_attention(q, k_pages, v_pages, lens, tables,
                                      interpret=interpret,
                                      block_skip=block_skip, q_rows=q_rows)
    kernel = functools.partial(paged_decode_attention, interpret=interpret,
                               block_skip=block_skip, q_rows=q_rows)
    return shard_map(
        lambda qs, ks, vs, ls, ts: kernel(qs, ks, vs, ls, ts),
        mesh=mesh,
        in_specs=(P(None, axis), P(None, None, axis), P(None, None, axis),
                  P(), P()),
        out_specs=P(None, axis),
        check_rep=False,
    )(q, k_pages, v_pages, lens, tables)
