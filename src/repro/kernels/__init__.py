from repro.kernels.ops import (decode_attention, decode_attention_sharded,
                               fc_forward, fc_gemv, ssd_scan)

__all__ = ["decode_attention", "decode_attention_sharded", "fc_forward",
           "fc_gemv", "ssd_scan"]
