from repro.kernels.ops import decode_attention, fc_forward, fc_gemv, ssd_scan

__all__ = ["decode_attention", "fc_forward", "fc_gemv", "ssd_scan"]
