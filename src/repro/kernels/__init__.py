from repro.kernels.ops import (decode_attention, decode_attention_sharded,
                               fc_forward, fc_gemv, paged_decode_attention,
                               paged_decode_attention_sharded, ssd_scan)

__all__ = ["decode_attention", "decode_attention_sharded", "fc_forward",
           "fc_gemv", "paged_decode_attention",
           "paged_decode_attention_sharded", "ssd_scan"]
