"""Pure-jnp oracles for the Pallas kernels.

These are the trusted direct implementations: no blocking, no online
softmax, no chunking — just the mathematical definition.  Every kernel test
sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,          # [b, nkv, g, hd]  (one decode token, GQA-grouped)
    k_cache: jax.Array,    # [b, S, nkv, hd]
    v_cache: jax.Array,    # [b, S, nkv, hd]
    lens: jax.Array,       # [b] valid cache lengths
) -> jax.Array:            # [b, nkv, g, hd]
    b, nkv, g, hd = q.shape
    skv = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhgk,bshk->bhgs", q, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(skv)[None, :] < lens[:, None]            # [b, S]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshk->bhgk", p.astype(v_cache.dtype), v_cache)
    return out.astype(q.dtype)


def decode_attention_window_ref(
    q: jax.Array,          # [b, nkv, t*g, hd]  (window, group)-row-major
    k_cache: jax.Array,    # [b, S, nkv, hd]
    v_cache: jax.Array,    # [b, S, nkv, hd]
    lens: jax.Array,       # [b] valid lengths, ALL t window tokens included
    q_rows: int,
) -> jax.Array:            # [b, nkv, t*g, hd]
    """Windowed decode attention oracle (TLP > 1 verify / chunk waves).

    Window row r sits at absolute position lens - q_rows + r and sees KV
    position j iff j < lens - (q_rows - 1) + r; rows are (window,
    group)-row-major within each KV head, matching the kernels' q layout.
    """
    b, nkv, tg, hd = q.shape
    g = tg // q_rows
    skv = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhgk,bshk->bhgs", q, k_cache).astype(jnp.float32) * scale
    row = jnp.arange(tg) // g                                   # [t*g]
    limit = lens[:, None] - (q_rows - 1) + row[None, :]         # [b, t*g]
    valid = jnp.arange(skv)[None, None, :] < limit[:, :, None]  # [b, t*g, S]
    s = jnp.where(valid[:, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshk->bhgk", p.astype(v_cache.dtype), v_cache)
    return out.astype(q.dtype)


def fc_gemv_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [m, K] @ w: [K, N] with f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def ssd_scan_ref(
    dtx: jax.Array,   # [b, nh, l, hp]   dt_t * x_t
    lt: jax.Array,    # [b, nh, l]       dt_t * A_h  (log-decay, f32)
    B: jax.Array,     # [b, l, n]
    C: jax.Array,     # [b, l, n]
) -> jax.Array:       # [b, nh, l, hp]
    """Sequential SSD recurrence — the definitional oracle.

    S_t = exp(lt_t) * S_{t-1} + dtx_t outer B_t ;  y_t = S_t @ C_t
    """
    b, nh, l, hp = dtx.shape
    n = B.shape[-1]
    f32 = jnp.float32

    def step(s, inp):
        dtx_t, lt_t, B_t, C_t = inp
        s = jnp.exp(lt_t)[..., None, None] * s + jnp.einsum(
            "bhp,bn->bhpn", dtx_t.astype(f32), B_t.astype(f32)
        )
        y = jnp.einsum("bhpn,bn->bhp", s, C_t.astype(f32))
        return s, y

    s0 = jnp.zeros((b, nh, hp, n), f32)
    xs = (
        jnp.moveaxis(dtx, 2, 0),
        jnp.moveaxis(lt, 2, 0).astype(f32),
        jnp.moveaxis(B, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(dtx.dtype)
