"""Flash-decode GQA attention Pallas kernel — the Attn-PIM analogue.

PAPI's Attn-PIM executes attention *next to the KV data* with modest compute
(1 FPU / 2 banks), because decode attention is always memory-bound: each KV
byte is read once per query — and that includes the TLP>1 verify windows
speculative decoding produces (§4–5): a t-token window still streams the
cache exactly once, amortized over t query rows.  The TPU-native
translation is a kernel whose HBM traffic is exactly one streaming pass
over the KV cache, with the online softmax state held in VMEM:

  grid = (batch, kv_heads, S // block_k)   last axis innermost/sequential
  K/V blocks:  [block_k, hd]   streamed HBM -> VMEM once
  Q block:     [R, hd]         R = q_rows * g query rows pinned per (b, h)
  scratch:     acc [R, hd] f32, m/l [R, 128] f32 running softmax state

Query windows (TLP > 1)
-----------------------
``q_rows=t`` generalizes the single decode token to a window of t query
rows per KV head group — the speculative verify step (TLP = spec window)
and chunked-prefill waves.  The R = t*g rows are (window, group)-row-major:
row = r * g + gg holds window token r of grouped head gg, all t*g rows
share one streaming KV pass and one MXU score matrix per block.  Masking
is intra-window causal: the rows sit at consecutive absolute positions
``lens - t .. lens - 1``, so KV position j is visible to window row r iff
``j < lens - (t - 1) + r``.  For q_rows=1 this degrades to the plain
``j < lens`` ragged mask, bit-identically.  ``lens >= q_rows`` is required
(every row must keep at least its own diagonal position, or its softmax
normalizer would be empty) — the engine guarantees it: lens = pos + t with
pos >= 0.

Masking uses per-request cache lengths (continuous batching => ragged),
delivered via scalar prefetch (`PrefetchScalarGridSpec`) so they are
available *before* each grid step's DMA is issued.

Ragged block skipping
---------------------
A continuous batch is ragged: slot A may hold 2000 cached tokens while slot
B holds 40, yet the grid runs `capacity // block_k` KV steps for both.  With
``block_skip=True`` (default) two things happen for blocks entirely past a
request's cache length:

  * the K/V `index_map` clamps the block index to the request's last valid
    block — consecutive grid steps then fetch the *same* block, which the
    Pallas pipeline recognizes and elides the redundant HBM->VMEM DMA;
  * the kernel body wraps the whole score/softmax/accumulate computation in
    a `pl.when(kb * block_k < length)` no-op, so fully-masked tiles spend
    neither MXU nor VPU cycles.

Numerics are bit-identical with skipping on or off for any `lens >= 1`
batch (tested): a fully-masked tile contributes p = exp(NEG_INF - m) = +0.0
and alpha = 1.0 exactly, i.e. nothing.  (For the degenerate lens == 0 the
skipped kernel returns zeros while the unskipped one would emit a uniform
average over garbage — the engine never produces lens < 1, it parks idle
slots at pos = 1.)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(
    lens_ref,      # SMEM [b] int32 — scalar-prefetched per-request lengths
    q_ref,         # [1, 1, R, hd]   R = q_rows * g, (window, group)-row-major
    k_ref,         # [1, block_k, 1, hd]
    v_ref,         # [1, block_k, 1, hd]
    o_ref,         # [1, 1, R, hd]
    acc_ref,       # VMEM [R, hd] f32
    m_ref,         # VMEM [R, 128] f32 (lane-padded running max)
    l_ref,         # VMEM [R, 128] f32 (lane-padded running sum)
    *,
    block_k: int,
    num_kb: int,
    block_skip: bool,
    q_rows: int = 1,
):
    i = pl.program_id(0)
    kb = pl.program_id(2)
    length = lens_ref[i]

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _compute():
        q = q_ref[0, 0]                                   # [g, hd]
        k = k_ref[0, :, 0]                                # [block_k, hd]
        v = v_ref[0, :, 0]                                # [block_k, hd]
        scale = 1.0 / math.sqrt(q.shape[-1])

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                         # [g, block_k]

        kv_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if q_rows == 1:
            # plain ragged mask — the seed kernel's exact expression
            limit = length
        else:
            # intra-window causal mask: window row r (= row-index // g) sits
            # at absolute position length - q_rows + r, so it sees KV
            # positions j < length - (q_rows - 1) + r
            g = s.shape[0] // q_rows
            row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
            limit = length - (q_rows - 1) + row
        s = jnp.where(kv_pos < limit, s, NEG_INF)

        m_prev = m_ref[:, 0:1]                            # [g, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)        # [g, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [g, block_k]
        alpha = jnp.exp(m_prev - m_new)                   # [g, 1]

        l_new = l_ref[:, 0:1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                 # [g, hd]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if block_skip:
        # fully-masked tile => no-op (the DMA was already elided by the
        # clamped index_map; this skips the compute as well)
        pl.when(kb * block_k < length)(_compute)
    else:
        _compute()

    @pl.when(kb == num_kb - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_k", "interpret", "block_skip", "q_rows"))
def decode_attention(
    q: jax.Array,          # [b, nkv, R, hd]   R = q_rows * g
    k_cache: jax.Array,    # [b, S, nkv, hd]
    v_cache: jax.Array,    # [b, S, nkv, hd]
    lens: jax.Array,       # [b] int32 valid lengths (ALL q_rows included)
    *,
    block_k: int = 512,
    interpret: bool | None = None,
    block_skip: bool = True,
    q_rows: int = 1,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, nkv, g, hd = q.shape
    assert g % q_rows == 0, (g, q_rows)
    skv = k_cache.shape[1]
    block_k = min(block_k, skv)
    assert skv % block_k == 0, (skv, block_k)
    num_kb = skv // block_k
    lens1 = lens.astype(jnp.int32).reshape(b)

    def q_index(i, j, kb, lens_ref):
        return (i, j, 0, 0)

    def kv_index(i, j, kb, lens_ref):
        if not block_skip:
            return (i, kb, j, 0)
        # clamp to the request's last valid block: repeated indices make the
        # pipeline skip the redundant fetch for fully-masked tiles
        last = jnp.maximum(pl.cdiv(lens_ref[i], block_k) - 1, 0)
        return (i, jnp.minimum(kb, last), j, 0)

    grid = (b, nkv, num_kb)
    kernel = functools.partial(_kernel, block_k=block_k, num_kb=num_kb,
                               block_skip=block_skip, q_rows=q_rows)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), q_index),
            pl.BlockSpec((1, block_k, 1, hd), kv_index),
            pl.BlockSpec((1, block_k, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), q_index),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="papi_decode_attention",
    )(lens1, q, k_cache, v_cache)


def decode_attention_sharded(
    q: jax.Array,          # [b, nkv, R, hd]   R = q_rows * g
    k_cache: jax.Array,    # [b, S, nkv, hd]
    v_cache: jax.Array,    # [b, S, nkv, hd]
    lens: jax.Array,       # [b] int32 valid lengths (ALL q_rows included)
    *,
    mesh,
    axis: str = "model",
    block_k: int = 512,
    interpret: bool | None = None,
    block_skip: bool = True,
    q_rows: int = 1,
) -> jax.Array:
    """One Attn-PIM unit per KV shard (§5.3): the kernel, `shard_map`-split
    over the KV-head dim of `axis`.

    Attention-PIM in the paper sits next to its slice of the KV cache and
    never talks to its neighbours; the head dim is the axis with exactly that
    property — each shard runs the full online-softmax pass over its local
    heads' KV stream and no cross-shard reduction exists, so the result is
    bit-identical to the unsharded kernel (tested).  Query windows
    (``q_rows > 1``, the speculative verify / chunked-prefill form) shard
    identically: the window rows ride the head dim they belong to, so each
    shard masks its own rows locally.  When the head count does not divide
    the axis (small GQA models on wide meshes) the unsharded kernel runs
    replicated instead — same divisibility fallback the rule tables use for
    weights.
    """
    nkv = q.shape[1]
    size = dict(mesh.shape).get(axis, 1)
    if size <= 1 or nkv % size != 0:
        return decode_attention(q, k_cache, v_cache, lens, block_k=block_k,
                                interpret=interpret, block_skip=block_skip,
                                q_rows=q_rows)
    kernel = functools.partial(decode_attention, block_k=block_k,
                               interpret=interpret, block_skip=block_skip,
                               q_rows=q_rows)
    return shard_map(
        lambda qs, ks, vs, ls: kernel(qs, ks, vs, ls),
        mesh=mesh,
        in_specs=(P(None, axis), P(None, None, axis), P(None, None, axis),
                  P()),
        out_specs=P(None, axis),
        check_rep=False,
    )(q, k_cache, v_cache, lens)
