"""Mamba2 SSD chunk-scan Pallas kernel.

The SSD algorithm's hot loop: per (batch, head), chunks are processed in
sequence; each chunk is three small dense matmuls (MXU work) plus a rank-1
state update, with the [hp, n] recurrent state living in VMEM scratch across
the sequential chunk axis:

  grid = (b, nh, n_chunks)        chunk axis innermost ("arbitrary")
  per chunk:  CB   = C_c @ B_c^T              [cs, cs]
              y    = (CB * L) @ dtx_c          intra-chunk, L = decay mask
                   + (exp(cum) * C_c) @ S^T    inter-chunk from carried state
              S    = exp(cum_last) * S + (E * dtx_c)^T @ B_c

Inputs are pre-discretized (dtx = dt*x, lt = dt*A) and the within-chunk
cumulative log-decay `cum` is precomputed by the wrapper — the kernel is pure
matmul + elementwise, mapping straight onto MXU/VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _kernel(
    dtx_ref,    # [1, 1, 1, cs, hp]
    cum_ref,    # [1, 1, 1, cs]  f32 inclusive within-chunk cumsum of lt
    b_ref,      # [1, 1, cs, n]
    c_ref,      # [1, 1, cs, n]
    o_ref,      # [1, 1, 1, cs, hp]
    state_ref,  # VMEM [hp, n] f32
):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    dtx = dtx_ref[0, 0, 0].astype(jnp.float32)        # [cs, hp]
    cum = cum_ref[0, 0, 0]                            # [cs]
    B = b_ref[0, 0].astype(jnp.float32)               # [cs, n]
    C = c_ref[0, 0].astype(jnp.float32)               # [cs, n]
    cs = dtx.shape[0]

    # intra-chunk: (C B^T ∘ L) @ dtx
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # [cs, cs]
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    y = jax.lax.dot_general(CB * L, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # [cs, hp]

    # inter-chunk: (exp(cum) * C) @ state^T
    state = state_ref[...]                                          # [hp, n]
    y += jax.lax.dot_general(
        jnp.exp(cum)[:, None] * C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0, 0, 0] = y.astype(o_ref.dtype)

    # state update: exp(cum_last) * state + (E*dtx)^T @ B
    e_to_end = jnp.exp(cum[-1] - cum)                               # [cs]
    s_chunk = jax.lax.dot_general(
        dtx * e_to_end[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                               # [hp, n]
    state_ref[...] = jnp.exp(cum[-1]) * state + s_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    dtx: jax.Array,   # [b, nh, l, hp]  dt_t * x_t
    lt: jax.Array,    # [b, nh, l]      dt_t * A_h (f32 log-decay)
    B: jax.Array,     # [b, l, n]
    C: jax.Array,     # [b, l, n]
    *,
    chunk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:       # [b, nh, l, hp]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, nh, l, hp = dtx.shape
    n = B.shape[-1]
    cs = min(chunk, l)
    assert l % cs == 0, (l, cs)
    nc = l // cs

    cum = jnp.cumsum(
        lt.astype(jnp.float32).reshape(b, nh, nc, cs), axis=-1
    )                                                  # [b, nh, nc, cs]
    dtx_c = dtx.reshape(b, nh, nc, cs, hp)
    B_c = B.reshape(b, nc, cs, n)
    C_c = C.reshape(b, nc, cs, n)

    grid = (b, nh, nc)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, cs, hp), lambda i, h, c: (i, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, cs, n), lambda i, h, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, cs, n), lambda i, h, c: (i, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, cs, hp), lambda i, h, c: (i, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, nc, cs, hp), dtx.dtype),
        scratch_shapes=[pltpu.VMEM((hp, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="papi_ssd_scan",
    )(dtx_c, cum, B_c, C_c)
    return out.reshape(b, nh, l, hp)
