"""Weight-streaming skinny matmul Pallas kernel — the FC-PIM analogue.

PAPI's FC-PIM executes the FC kernel when it is memory-bound (low RLP*TLP):
each weight element is read from DRAM once and reused across the few
activation rows.  The TPU translation: a matmul kernel organized so the
weight matrix makes exactly one HBM -> VMEM pass, with the skinny activation
block pinned in VMEM for the whole kernel:

  grid = (N // block_n, K // block_k)    k innermost (accumulate in scratch)
  x block: [m, block_k]      m = RLP*TLP rows, pinned (same block all n)
  w block: [block_k, block_n] streamed once
  acc:     [m, block_n] f32 scratch

Block tuning: unless the caller pins block sizes, `_auto_blocks` picks the
largest divisors of (K, N) whose *double-buffered* working set fits a
conservative VMEM budget — the pipeline overlaps the next weight tile's DMA
with the current tile's FLOPs, so both buffers must be resident at once.
Bigger tiles amortize grid/DMA overhead; the budget keeps two w-tiles, two
x-tiles, the f32 accumulator and the output block co-resident.

When RLP*TLP is large the MXU path (plain jnp.dot / XLA) wins — that flip is
exactly PAPI's scheduling decision, made by `core.scheduler` and validated by
`core.calibration` on this very pair of implementations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

# Conservative per-core VMEM budget for the kernel's working set (real VMEM
# is ~16 MiB; leave headroom for the pipeline's own bookkeeping).
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _largest_divisor(dim: int, target: int) -> int:
    """Largest divisor of dim that is <= target."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


def _working_set_bytes(m: int, bk: int, bn: int, itemsize: int) -> int:
    # 2x for double buffering of the streamed/pinned input tiles; the f32
    # accumulator and output tile are single-buffered.
    return (2 * bk * bn * itemsize        # w tiles (streamed)
            + 2 * m * bk * itemsize       # x tiles (pinned, revolving)
            + m * bn * 4                  # acc scratch (f32)
            + m * bn * itemsize)          # output tile


def _auto_blocks(m: int, K: int, N: int, itemsize: int) -> tuple[int, int]:
    """Pick (block_k, block_n) fitting the double-buffered VMEM budget."""
    for target in (1024, 768, 512, 384, 256, 128, 64, 32, 16, 8):
        bk = _largest_divisor(K, target)
        bn = _largest_divisor(N, target)
        if _working_set_bytes(m, bk, bn, itemsize) <= _VMEM_BUDGET_BYTES:
            return bk, bn
    return _largest_divisor(K, 8), _largest_divisor(N, 8)


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, num_kb: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kb == num_kb - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "block_n", "interpret"))
def fc_gemv(
    x: jax.Array,      # [m, K]  (m = RLP*TLP, small)
    w: jax.Array,      # [K, N]
    *,
    block_k: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, K = x.shape
    K2, N = w.shape
    assert K == K2
    auto_k, auto_n = _auto_blocks(m, K, N, x.dtype.itemsize)
    block_k = auto_k if block_k is None else min(block_k, K)
    block_n = auto_n if block_n is None else min(block_n, N)
    assert K % block_k == 0 and N % block_n == 0, (K, N, block_k, block_n)
    num_kb = K // block_k

    grid = (N // block_n, num_kb)
    kernel = functools.partial(_kernel, num_kb=num_kb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda n, k: (0, k)),
            pl.BlockSpec((block_k, block_n), lambda n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((m, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            # n parallel, k sequential: the k accumulation must stay ordered,
            # the n tiles are independent so the pipeline can double-buffer
            # the weight stream across both axes.
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="papi_fc_gemv",
    )(x, w)
