"""Weight-streaming skinny matmul Pallas kernel — the FC-PIM analogue.

PAPI's FC-PIM executes the FC kernel when it is memory-bound (low RLP*TLP):
each weight element is read from DRAM once and reused across the few
activation rows.  The TPU translation: a matmul kernel organized so the
weight matrix makes exactly one HBM -> VMEM pass, with the skinny activation
block pinned in VMEM for the whole kernel:

  grid = (N // block_n, K // block_k)    k innermost (accumulate in scratch)
  x block: [m, block_k]      m = RLP*TLP rows, pinned (same block all n)
  w block: [block_k, block_n] streamed once
  acc:     [m, block_n] f32 scratch

When RLP*TLP is large the MXU path (plain jnp.dot / XLA) wins — that flip is
exactly PAPI's scheduling decision, made by `core.scheduler` and validated by
`core.calibration` on this very pair of implementations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, num_kb: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kb == num_kb - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "block_n", "interpret"))
def fc_gemv(
    x: jax.Array,      # [m, K]  (m = RLP*TLP, small)
    w: jax.Array,      # [K, N]
    *,
    block_k: int = 512,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, K = x.shape
    K2, N = w.shape
    assert K == K2
    block_k = min(block_k, K)
    block_n = min(block_n, N)
    assert K % block_k == 0 and N % block_n == 0, (K, N, block_k, block_n)
    num_kb = K // block_k

    grid = (N // block_n, num_kb)
    kernel = functools.partial(_kernel, num_kb=num_kb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda n, k: (0, k)),
            pl.BlockSpec((block_k, block_n), lambda n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((m, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="papi_fc_gemv",
    )(x, w)
