"""Jit'd public wrappers over the Pallas kernels with backend dispatch.

On TPU the Pallas kernels run compiled (Mosaic); on CPU — including the
multi-pod dry-run, which lowers the XLA path — they run in interpret mode
for validation, or the callers use the pure-XLA equivalents in
`repro.models.layers` / `repro.models.ssm`.

`fc_variant` is the runtime switch the PAPI scheduler flips: "pim" selects
the weight-streaming fc_gemv kernel (memory-bound regime), "pu" the plain
MXU dot (compute-bound regime).  Both produce identical numerics (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_sharded)
from repro.kernels.fc_gemv import fc_gemv
from repro.kernels.paged_decode_attention import (
    paged_decode_attention, paged_decode_attention_sharded)
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["decode_attention", "decode_attention_sharded", "fc_gemv",
           "paged_decode_attention", "paged_decode_attention_sharded",
           "ssd_scan", "fc_forward"]


def fc_forward(x: jax.Array, w: jax.Array, variant: str = "pu",
               interpret: bool | None = None) -> jax.Array:
    """FC kernel with PAPI's two execution paths.

    x: [m, K], w: [K, N].  variant in {"pu", "pim"}.
    """
    if variant == "pim":
        return fc_gemv(x, w, interpret=interpret)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
