from repro.data.pipeline import DataConfig, batches, make_batch

__all__ = ["DataConfig", "batches", "make_batch"]
