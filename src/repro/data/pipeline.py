"""Deterministic, resumable, sharded synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — so a restarted run
resumes mid-stream bit-identically from the checkpointed step index, and
each data shard draws disjoint streams.  Token statistics follow a Zipfian
unigram over the arch's vocab (more realistic softmax/load-balancing
behaviour than uniform; MoE routers see realistic skew).

Family-aware: produces frames for audio archs, patch embeddings + M-RoPE
positions for VLM archs, and plain token/target pairs otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    num_shards: int = 1
    shard: int = 0


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard])
    )


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    ranks = rng.zipf(1.2, size=shape).astype(np.int64)
    return np.minimum(ranks - 1, vocab - 1).astype(np.int32)


def make_batch(mcfg: ModelConfig, dcfg: DataConfig, step: int) -> dict:
    """One training batch for this shard at this step."""
    rng = _rng(dcfg, step)
    b = dcfg.batch // dcfg.num_shards
    s = dcfg.seq_len
    if mcfg.family == "audio":
        frames = rng.standard_normal((b, s, mcfg.d_model)).astype(np.float32)
        mask = rng.random((b, s)) < 0.3
        targets = _zipf_tokens(rng, (b, s), mcfg.vocab_size)
        return {"frames": frames, "mask": mask, "targets": targets,
                "target_mask": mask.astype(np.float32)}
    if mcfg.family == "vlm":
        sv = s // 4
        st = s - sv
        toks = _zipf_tokens(rng, (b, st + 1), mcfg.vocab_size)
        patches = rng.standard_normal((b, sv, mcfg.d_model)).astype(np.float32)
        positions = np.broadcast_to(np.arange(s)[None, None, :], (b, 3, s))
        return {
            "tokens": toks[:, :-1], "targets": toks[:, 1:],
            "patch_embeds": patches, "positions": np.ascontiguousarray(positions),
        }
    toks = _zipf_tokens(rng, (b, s + 1), mcfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def batches(mcfg: ModelConfig, dcfg: DataConfig,
            start_step: int = 0) -> Iterator[dict]:
    """Resumable stream: `batches(..., start_step=k)` reproduces exactly the
    stream a fresh run would see from step k (deterministic resume)."""
    step = start_step
    while True:
        yield make_batch(mcfg, dcfg, step)
        step += 1
