"""Quickstart: the PAPI mechanism in 60 lines.

Builds a small decoder LM, serves a handful of requests through the PAPI
engine, and prints the scheduler's dynamic FC-path decisions as request-
level parallelism decays — Figure 5(d) live.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest

def main():
    # a reduced qwen2-family config that runs on CPU in seconds
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    engine = PapiEngine(
        cfg, params,
        max_slots=8, cache_capacity=128, prefill_len=16,
        alpha=4.0,          # memory-boundedness threshold (RLP*TLP units)
    )

    # staggered output lengths => RLP decays over time (paper Fig. 3)
    for i in range(8):
        engine.submit(ServeRequest(
            req_id=i, prompt=[3 + i, 5, 7, 11], max_new_tokens=4 + 6 * i))

    results = engine.run()

    print(f"{len(results)} requests completed in {engine.iteration} iterations\n")
    print("iter  RLP  TLP  AI=RLP*TLP  FC path   (alpha = 4.0)")
    for s in engine.stats:
        marker = "<- reschedule" if any(
            e.iteration == s.iteration and e.rescheduled
            for e in engine.scheduler.events) else ""
        print(f"{s.iteration:4d}  {s.rlp:3d}  {s.tlp:3d}  {s.ai_estimate:9.1f}"
              f"  {s.fc_variant:8s}{marker}")
    print(f"\nreschedules: {engine.scheduler.num_reschedules} "
          "(compute-bound 'pu' while RLP is high -> memory-bound 'pim' as "
          "requests finish)")

if __name__ == "__main__":
    main()
