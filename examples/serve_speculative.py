"""Speculative decoding + continuous batching through the PAPI engine.

A draft model proposes 3-token windows that the target verifies in a single
TLP=3 decode step; the scheduler sees AI = RLP*TLP and keeps the FC kernels
on the compute-optimized path while parallelism is high.  Requests arrive
mid-flight (mixed continuous batching).

    PYTHONPATH=src python examples/serve_speculative.py
    PYTHONPATH=src python examples/serve_speculative.py --paged
    PYTHONPATH=src python examples/serve_speculative.py --paged --attn-pim

``--paged`` swaps the per-slot KV slabs for the paged Attn-PIM bank-row
layout (pooled pages + block tables, page-budgeted admission; speculative
rejections return their pages to the pool) — the token streams are
identical, only the memory economics change.

``--attn-pim`` routes the whole speculative loop's attention through the
Pallas flash-decode kernels: the draft's single-token steps AND the
target's TLP=3 verify windows (the windowed kernel applies the
intra-window causal mask; with ``--paged`` it resolves pages inside its
block-table index_map — no gathered pool view).  Token streams are again
identical: the kernel moves bytes differently, never the argmax.

One request carries a prompt 3x the compiled prefill window: admission
chunks it through the fixed-shape prefill (both caches, target and draft,
fill at the same offsets), so long prompts are served untruncated.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (Attn-PIM bank-row pages)")
    ap.add_argument("--attn-pim", action="store_true",
                    help="draft steps and TLP=3 verify windows through the "
                         "(windowed) Pallas flash-decode kernels")
    args = ap.parse_args()

    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # self-draft (same weights) => high acceptance; a real deployment uses a
    # distilled draft model
    draft = (cfg, params)

    engine = PapiEngine(
        cfg, params, max_slots=4, cache_capacity=128, prefill_len=16,
        alpha=6.0, spec_len=3, draft=draft,
        kv_layout="paged" if args.paged else "dense", page_size=16,
        attn_pim=args.attn_pim,
    )
    rng = np.random.default_rng(0)
    for i in range(4):
        # request 0 carries a 48-token prompt — 3x the compiled 16-token
        # prefill window — which admission chunks through the fixed-shape
        # prefill (no truncation; its KV lands at running offsets)
        plen = 48 if i == 0 else 8
        engine.submit(ServeRequest(
            i, rng.integers(3, cfg.vocab_size, plen).tolist(),
            max_new_tokens=18))

    # run a few iterations, then new requests arrive mid-stream
    for _ in range(3):
        engine.step()
    for i in range(4, 8):
        engine.submit(ServeRequest(
            i, rng.integers(3, cfg.vocab_size, 8).tolist(),
            max_new_tokens=12))
    results = engine.run()

    print(f"{len(results)} requests done in {engine.iteration} iterations")
    acc = [s.accepted for s in engine.stats if s.new_tokens > 0]
    print(f"mean accepted tokens per 3-token window: {np.mean(acc):.2f}")
    print(f"tokens/iteration: "
          f"{sum(len(r.tokens) for r in results) / engine.iteration:.2f} "
          "(>1 => speculative parallelism paying off)")
    if engine.kv is not None:
        st = engine.kv.stats()
        print(f"kv pages: watermark {st.watermark}/{st.num_pages} "
              f"({st.page_size} tokens each) — rejected windows returned "
              "their pages to the pool")

if __name__ == "__main__":
    main()
