"""Speculative decoding + continuous batching through the PAPI engine.

A draft model proposes 3-token windows that the target verifies in a single
TLP=3 decode step; the scheduler sees AI = RLP*TLP and keeps the FC kernels
on the compute-optimized path while parallelism is high.  Requests arrive
mid-flight (mixed continuous batching).

    PYTHONPATH=src python examples/serve_speculative.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest

def main():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # self-draft (same weights) => high acceptance; a real deployment uses a
    # distilled draft model
    draft = (cfg, params)

    engine = PapiEngine(
        cfg, params, max_slots=4, cache_capacity=128, prefill_len=16,
        alpha=6.0, spec_len=3, draft=draft,
    )
    rng = np.random.default_rng(0)
    for i in range(4):
        engine.submit(ServeRequest(
            i, rng.integers(3, cfg.vocab_size, 8).tolist(),
            max_new_tokens=18))

    # run a few iterations, then new requests arrive mid-stream
    for _ in range(3):
        engine.step()
    for i in range(4, 8):
        engine.submit(ServeRequest(
            i, rng.integers(3, cfg.vocab_size, 8).tolist(),
            max_new_tokens=12))
    results = engine.run()

    print(f"{len(results)} requests done in {engine.iteration} iterations")
    acc = [s.accepted for s in engine.stats if s.new_tokens > 0]
    print(f"mean accepted tokens per 3-token window: {np.mean(acc):.2f}")
    print(f"tokens/iteration: "
          f"{sum(len(r.tokens) for r in results) / engine.iteration:.2f} "
          "(>1 => speculative parallelism paying off)")

if __name__ == "__main__":
    main()
