"""End-to-end training driver: a ~100M-parameter dense LM for a few hundred
steps on the synthetic Zipf stream, with gradient accumulation, async
checkpointing (+ crash/resume demo) and the straggler watchdog.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import shutil

import dataclasses

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.training import AdamWConfig, TrainConfig, run_training

# ~100M params: 12 layers, d_model 512, GQA 8/4 heads, 32k vocab
CFG_100M = ModelConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32_000,
    head_dim=64, mlp="swiglu", norm="rmsnorm", dtype="float32",
    max_seq_len=1024,
)

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    print(f"params: {CFG_100M.param_count() / 1e6:.1f}M")
    shutil.rmtree(args.ckpt, ignore_errors=True)

    tcfg = TrainConfig(
        steps=args.steps, accum=2, remat=True, checkpoint_every=50,
        checkpoint_dir=args.ckpt, log_every=20,
    )
    dcfg = DataConfig(batch=8, seq_len=256)
    ocfg = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)

    # phase 1: train to 60% of the budget, then simulate a crash
    t1 = dataclasses.replace(tcfg, steps=int(args.steps * 0.6))
    res1 = run_training(CFG_100M, t1, dcfg, ocfg)
    print(f"\n-- simulated preemption at step {res1.final_step} --\n")

    # phase 2: restart resumes from the latest checkpoint, same data stream
    res2 = run_training(CFG_100M, tcfg, dcfg, ocfg, resume=True)
    print(f"\nresumed from step {res2.resumed_from}; "
          f"loss {res1.losses[0]:.3f} -> {res2.losses[-1]:.3f}; "
          f"stragglers flagged: {res2.straggler_events}")

if __name__ == "__main__":
    main()
