"""MoE + PAPI (§6.5): expert sparsity changes the scheduling decision.

For an MoE arch the per-expert parallelism is RLP*TLP*top_k/E, so the same
batch that is compute-bound for a dense model stays memory-bound for its
expert FCs — PAPI's scheduler accounts for that via
`core.ai.effective_parallelism`.  This example trains a small OLMoE-family
model a few steps (router + capacity dispatch + aux loss all engaged), then
contrasts the scheduling decision against a dense twin.

    PYTHONPATH=src python examples/moe_expert_parallel.py
"""
from repro.configs import get_config
from repro.core.ai import effective_parallelism
from repro.core.scheduler import PapiScheduler
from repro.data.pipeline import DataConfig
from repro.training import AdamWConfig, TrainConfig, run_training

def main():
    moe = get_config("olmoe-1b-7b")
    dense = get_config("granite-8b")

    print("scheduling view at RLP=64, TLP=2 (alpha = 32):")
    for cfg in (dense, moe):
        eff = effective_parallelism(cfg, 64, 2)
        sched = PapiScheduler(cfg, alpha=32.0, tlp=2)
        sched.initial_schedule(64, 2)
        print(f"  {cfg.name:16s} effective parallelism = {eff:6.1f} "
              f"-> FC on {sched.fc_assignment!r}")
    print("(the MoE's expert FCs stay on the memory-optimized path: "
          "64*2*8/64 = 16 <= 32, exactly the paper's §6.5 observation)\n")

    cfg = moe.reduced()
    print(f"training reduced {cfg.name}: {cfg.param_count()/1e6:.1f}M params,"
          f" {cfg.moe.num_experts} experts top-{cfg.moe.top_k}")
    res = run_training(
        cfg,
        TrainConfig(steps=30, checkpoint_every=1000, log_every=10,
                    checkpoint_dir="/tmp/repro_moe_ckpt", remat=False),
        DataConfig(batch=4, seq_len=64),
        AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30),
    )
    print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} over 30 steps")

if __name__ == "__main__":
    main()
