"""Scheduler-cost microbenchmark (the paper's 'low-cost' claim, §5.2):
wall-clock of one full observe->decide cycle on the host, plus kernel-path
dispatch latency.  The decision must be negligible vs a decode iteration
(ms-scale on the paper's hardware)."""
import time

from repro.configs.paper_models import LLAMA_65B
from repro.core.scheduler import PapiScheduler


def rows():
    sched = PapiScheduler(LLAMA_65B, alpha=32.0, tlp=2)
    sched.initial_schedule(64, 2)
    toks = [5] * 63 + [2]
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        sched.observe_outputs(toks, admitted=1)
    dt = (time.perf_counter() - t0) / n
    return [
        ("sched_observe_decide_us", dt * 1e6,
         "per decoding iteration, batch=64"),
        ("sched_negligible_vs_1ms_iter", float(dt < 1e-4), ""),
    ]
