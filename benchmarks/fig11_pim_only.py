"""Fig. 11 — PIM-only PAPI (FC-PIM + Attn-PIM, no GPU) vs AttAcc-only,
decode phase, creative-writing.  Paper: 2.3x average, rising with
parallelism (1.6x at b4/s1 -> 2.7x at b64/s4)."""
import numpy as np

from repro.configs.paper_models import LLAMA_65B
from repro.core.system import compare_systems
from repro.core.traces import generate_trace


def rows():
    trace = generate_trace("creative-writing", 64, seed=0)
    out = []
    sp = []
    for bs in (4, 16, 64):
        for sl in (1, 2, 4):
            res = compare_systems(LLAMA_65B, trace[:bs], bs, sl,
                                  systems=("pim_only_papi", "attacc_only"))
            r = res["attacc_only"].time_s / res["pim_only_papi"].time_s
            sp.append(r)
            out.append((f"fig11_b{bs}_s{sl}_pimonly_speedup", r, ""))
    out.append(("fig11_MEAN_pimonly_speedup", float(np.mean(sp)),
                "paper=2.3"))
    out.append(("fig11_rises_with_parallelism", float(sp[-1] > sp[0]),
                f"b4s1={sp[0]:.2f} -> b64s4={sp[-1]:.2f} (paper 1.6->2.7)"))
    return out
