"""Fig. 4 — FC kernel latency across parallelization levels on A100 /
HBM-PIM / AttAcc, normalized to A100.  Validates the crossover: PIM wins at
low (batch, spec), the GPU at high."""
from repro.configs.paper_models import GPT3_66B
from repro.core import pim
from repro.core.system import N_FC_DEVICES


def _pim_fc_time(dev, m, h):
    # weights 2D-block distributed over the 30 weight-holding devices (§6.4)
    return dev.gemv_time(m, h, max(h // N_FC_DEVICES, 1))


def rows():
    h = GPT3_66B.d_model
    out = []
    for bs, sl in [(1, 8), (4, 2), (4, 8), (16, 2), (16, 8), (64, 4)]:
        m = bs * sl
        t_gpu = pim.gpu_fc_time(m, h, h)
        for name, dev in (("hbmpim", pim.HBM_PIM), ("attacc", pim.ATTACC)):
            t = _pim_fc_time(dev, m, h)
            out.append((f"fig4_{name}_b{bs}_s{sl}_norm_latency", t / t_gpu,
                        "<1 => PIM faster than A100"))
    return out
