"""Fig. 8 — end-to-end decode speedup + energy efficiency on the Dolly
creative-writing trace for LLaMA-65B / GPT-3 66B / GPT-3 175B, batch sizes
{4,16,64} x speculation {1,2,4}, normalized to A100+AttAcc.

Paper headline (averages over the setting grid): PAPI is 1.8x vs
A100+AttAcc, 1.9x vs A100+HBM-PIM, 11.1x vs AttAcc-only; energy 3.4x vs
A100+AttAcc."""
import numpy as np

from repro.configs.paper_models import GPT3_66B, GPT3_175B, LLAMA_65B
from repro.core.system import compare_systems
from repro.core.traces import generate_trace

SETTINGS = [(b, s) for b in (4, 16, 64) for s in (1, 2, 4)]


def rows():
    trace = generate_trace("creative-writing", 64, seed=0)
    out = []
    speed = {"a100_attacc": [], "a100_hbmpim": [], "attacc_only": []}
    energy = {"a100_attacc": []}
    for cfg in (LLAMA_65B, GPT3_66B, GPT3_175B):
        for bs, sl in SETTINGS:
            res = compare_systems(cfg, trace[:bs], bs, sl)
            papi = res["papi"]
            for s in speed:
                sp = res[s].time_s / papi.time_s
                speed[s].append(sp)
                out.append((f"fig8_speedup_vs_{s}_{cfg.name}_b{bs}_s{sl}",
                            sp, "normalized to that baseline"))
            energy["a100_attacc"].append(
                res["a100_attacc"].energy_per_token / papi.energy_per_token)
    for s, v in speed.items():
        paper = {"a100_attacc": 1.8, "a100_hbmpim": 1.9,
                 "attacc_only": 11.1}[s]
        out.append((f"fig8_MEAN_speedup_vs_{s}", float(np.mean(v)),
                    f"paper={paper}"))
    out.append(("fig8_MEAN_energy_eff_vs_a100_attacc",
                float(np.mean(energy["a100_attacc"])), "paper=3.4"))
    return out
