"""Fig. 10 — RLP/TLP sensitivity, LLaMA-65B, creative-writing.
(a) batch 4..128 at spec 1: AttAcc-only beats A100+AttAcc at batch 4, loses
badly at high batch; PAPI best everywhere.
(b) spec 1..8 at batch 4: PAPI's edge over A100+AttAcc shrinks as TLP grows
(more FC kernels land on the GPU — convergence the paper predicts)."""
from repro.configs.paper_models import LLAMA_65B
from repro.core.system import compare_systems
from repro.core.traces import generate_trace


def rows():
    trace = generate_trace("creative-writing", 128, seed=0)
    out = []
    for bs in (4, 16, 32, 64, 128):
        res = compare_systems(LLAMA_65B, trace[:bs], bs, 1,
                              systems=("papi", "a100_attacc", "attacc_only"))
        papi = res["papi"].time_s
        out.append((f"fig10a_b{bs}_a100attacc_over_papi",
                    res["a100_attacc"].time_s / papi, ""))
        out.append((f"fig10a_b{bs}_attacconly_over_papi",
                    res["attacc_only"].time_s / papi, ""))
    ratios = []
    for sl in (1, 2, 4, 8):
        res = compare_systems(LLAMA_65B, trace[:4], 4, sl,
                              systems=("papi", "a100_attacc", "attacc_only"))
        r = res["a100_attacc"].time_s / res["papi"].time_s
        ratios.append(r)
        out.append((f"fig10b_s{sl}_a100attacc_over_papi", r,
                    "paper avg 1.5x; decreases with TLP"))
        out.append((f"fig10b_s{sl}_attacconly_over_papi",
                    res["attacc_only"].time_s / res["papi"].time_s,
                    "paper avg 3.0x"))
    out.append(("fig10b_speedup_decreases_with_tlp",
                float(ratios[0] > ratios[-1]),
                f"s1={ratios[0]:.2f} -> s8={ratios[-1]:.2f}"))
    return out
