"""Fig. 2 — A100 roofline of FC vs attention kernels (OPT-30B).

(a) batch sweep at spec=8; (b) spec sweep at batch=32.  Validates: FC flips
to compute-bound at batch>32 (spec 8) / spec>6 (batch 32); attention stays
memory-bound at every setting."""
from repro.configs.paper_models import OPT_30B
from repro.core import pim
from repro.core.ai import attention_ai, fc_ai_exact

RIDGE = pim.GPU_PEAK_FLOPS / pim.GPU_HBM_BW   # A100 roofline ridge point


def rows():
    h = OPT_30B.d_model
    out = []
    for bs in (4, 8, 16, 32, 64, 128):
        ai = fc_ai_exact(bs * 8, h)
        out.append(("fig2a_fc_ai_b%d_s8" % bs, ai,
                    "compute-bound" if ai > RIDGE else "memory-bound"))
        out.append(("fig2a_attn_ai_b%d_s8" % bs, attention_ai(8),
                    "memory-bound"))
    for sl in (2, 4, 6, 8):
        ai = fc_ai_exact(32 * sl, h)
        out.append(("fig2b_fc_ai_b32_s%d" % sl, ai,
                    "compute-bound" if ai > RIDGE else "memory-bound"))
        out.append(("fig2b_attn_ai_b32_s%d" % sl, attention_ai(sl),
                    "memory-bound"))
    out.append(("fig2_ridge_flops_per_byte", RIDGE, "A100 312T/1935G"))
    return out
