"""Engine hot-path microbenchmark: per-iteration wall time and host-device
transfer counts, device-resident (fused) vs the seed's host-looped reference.

Measures what PR 1 changed:
  * plain decode  — fused argmax-on-device vs eager greedy + fetch;
  * speculative   — one jitted scan (draft k steps + verify + accept +
    rewind, ONE fetch) vs k per-step fetches + a verify fetch + a per-slot
    Python accept loop.

Transfers are counted by the engine itself: every device->host sync goes
through `PapiEngine._fetch` (see engine.py docstring), so the numbers are
actual round-trip counts, not estimates.  Wall times are medians over
post-warmup iterations with `jax.block_until_ready` semantics implied by the
fetch in every iteration.

Writes BENCH_engine.json next to the repo root so the perf trajectory is
tracked from this PR onward.

``--mesh dp,tp`` switches to mesh mode: ONLY the unsharded-vs-sharded
engine A/B runs (§5.3 layout: FC-PIM banks on the tensor axis, KV sharded
per Attn-PIM unit), on dp*tp forced host devices, and the result is MERGED
into an existing BENCH_engine.json under a "sharded" key — the fused/legacy
baselines are never remeasured under forced devices (they timeshare the
cores and would silently inflate).  Mesh mode exits 1 if the sharded token
streams diverge from the unsharded engine's; on CPU its throughput delta
measures partitioning overhead, not speedup.

``--kv paged`` A/Bs the dense per-slot slab layout against the paged
Attn-PIM bank-row layout (`serving/kv_pages.py`): decode throughput and
peak *resident* KV bytes (dense always holds its full slabs; paged
residency is the page-pool watermark) on a mixed-length greedy +
speculative workload.  The paged engine's block tables are capped at the
dense slab's context (``max_blocks = cache_capacity / page_size``) so both
sides bound per-request context identically — the pool-wide default table
makes the XLA path gather a pool-sized view per decode step, which charges
the LAYOUT for a 4x context-bound mismatch (speculative pays it 5x per
iteration: k draft steps + the verify).  The section merges into
BENCH_engine.json under a "paged" key and the run exits 1 if the paged
token streams diverge from the dense engine's — the same identity gate as
``--mesh``.

The same invocation then A/Bs the paged SPECULATIVE engine's two
attention routes — XLA page-gather vs the windowed block-table Pallas
kernel (``attn_pim=True``: draft steps, TLP=k verify windows, and chunk
waves all resolve pages inside the kernel index_map; `gather_kv_pages`
never traces) — under the same exit-1 token-identity gate, merged under
"paged_spec_attn_pim".  On CPU both kernels run in interpret mode, so the
throughput delta measures interpret overhead, not the kernel: the win
(one streaming pass, no materialized pool view) is a TPU property; the
gate here is token identity.

``--long-prompt`` A/Bs chunked admission against the one-shot window: the
same engine code runs long prompts (up to 6x) through an 8-token prefill
window (chunk waves via `models.prefill_chunk`) and through a 128-token
window that holds every prompt one-shot — the pre-chunking admission path.
The section merges under a "long_prompt" key and the run exits 1 unless
every token stream (a short <= window prompt included) is bit-identical
across the two: chunking must change compile-shape economics, never
tokens.

``--pressure`` runs an oversubscribed paged trace (6x more page demand
than the pool holds, preemption enabled) against the unconstrained dense
reference: every request must COMPLETE with a bounded first-admission
delay — the pre-preemption engine deferred the head of the queue
indefinitely under a held pool — and the token streams must stay
bit-identical to the reference, both for requests that were never
preempted (the gate `tools/check_bench.py` enforces) and for the
preempted ones (requeue recomputes `prompt + tokens-so-far` through
chunked prefill).  Merges a "pressure" section into BENCH_engine.json.

``--arrivals RATE`` drives the continuous-batching streaming front end
(`PapiEngine.serve`) with a seeded Poisson arrival process (RATE requests
per iteration expected) across all four serving combos — greedy/speculative
x dense/paged — and checks each against the OFFLINE oracle (same requests,
`submit()` + `run()`): streamed tokens must be bit-identical, every request
must complete, and the iteration-valued latency percentiles (queue delay,
TTFT; deterministic for a fixed seed) plus wall-clock TTFT/TPOT p50/p99 are
merged under an "arrivals" key.  `tools/check_bench.py` gates completion,
identity, and a bounded p99 TTFT.  Exits 1 on any divergence or lost
request.

``--trace PATH`` (arrivals mode only) re-runs the spec_dense combo with a
live `repro.serving.telemetry.Tracer`, writes the Chrome trace to PATH,
and merges a "telemetry" section: traced vs untraced tok/s and median
per-iteration wall (the overhead_frac `tools/check_bench.py` gates at
TELEMETRY_OVERHEAD_CEIL) plus a tokens_bit_identical flag proving the
observation layer never perturbs the streams.

``--crash-recovery`` gates the durability layer (`serving/journal.py`):
each serving combo — greedy/speculative x dense/paged — runs against a
write-ahead journal, is killed mid-trace by the deterministic `crash`
fault at several iterations k, and a FRESH engine `restore()`s from the
journal and completes the trace.  The union of pre-crash durable finishes
(reconstructed from the journal alone) and post-crash results must cover
every request exactly once with token streams bit-identical to an
uninterrupted oracle run.  Merges a "recovery" section into
BENCH_engine.json (`tools/check_bench.py` gates completion, identity, and
zero duplicate finishes) and exits 1 on any divergence.

Usage:  PYTHONPATH=src python benchmarks/engine_hotpath.py [--spec-len 4]
        PYTHONPATH=src python benchmarks/engine_hotpath.py --mesh 1,8
        PYTHONPATH=src python benchmarks/engine_hotpath.py --kv paged
        PYTHONPATH=src python benchmarks/engine_hotpath.py --long-prompt
        PYTHONPATH=src python benchmarks/engine_hotpath.py --pressure
        PYTHONPATH=src python benchmarks/engine_hotpath.py --arrivals 0.5
        PYTHONPATH=src python benchmarks/engine_hotpath.py --crash-recovery
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

ROOT = Path(__file__).resolve().parent.parent


def run_engine(cfg, params, draft_params, *, fused: bool, spec_len: int,
               n_requests: int = 6, max_new: int = 20, mesh=None,
               max_new_fn=None, eos_token: int = 1, prefill_len: int = 8,
               cache_capacity: int = 64, prompt_fn=None, **engine_kw):
    from repro.serving import PapiEngine, ServeRequest
    draft = (cfg, draft_params) if spec_len > 1 else None
    eng = PapiEngine(
        cfg, params,
        max_slots=4, cache_capacity=cache_capacity, prefill_len=prefill_len,
        alpha=6.0, eos_token=eos_token, spec_len=spec_len, draft=draft,
        fused=fused, mesh=mesh, **engine_kw,
    )
    for i in range(n_requests):
        n = max_new_fn(i) if max_new_fn is not None else max_new
        prompt = prompt_fn(i) if prompt_fn is not None else [3 + i, 5, 7]
        eng.submit(ServeRequest(i, prompt, max_new_tokens=n))
    results = eng.run(max_iterations=400)

    # decode-only iterations after compile warmup (first 2 iterations carry
    # trace+compile time; admission iterations carry the prefill fetch)
    decode_iters = [s for s in eng.stats[2:] if s.new_tokens > 0]
    if not decode_iters:
        decode_iters = [s for s in eng.stats if s.new_tokens > 0]
    walls = [s.wall_s for s in decode_iters]
    transfers = [s.transfers for s in decode_iters]
    # KV memory accounting: dense reserves its full slabs for the whole
    # run; paged residency is the page-pool watermark (peak pages actually
    # mapped), the utilization win the paged layout exists for
    def cache_bytes(c):
        return sum(c[k2].size * c[k2].dtype.itemsize
                   for k2 in ("k", "v") if c is not None and k2 in c)

    reserved = cache_bytes(eng.cache) + cache_bytes(eng.draft_cache)
    if eng.kv is not None:
        per_page = reserved // (eng.kv.alloc.num_pages + 1)
        resident = eng.kv.alloc.watermark * per_page
    else:
        resident = reserved
    metrics = {
        "fused": fused,
        "spec_len": spec_len,
        "iterations": len(eng.stats),
        "decode_iterations_measured": len(decode_iters),
        "wall_s_per_iter_median": statistics.median(walls),
        "wall_s_per_iter_mean": statistics.fmean(walls),
        "transfers_per_iter_mean": statistics.fmean(transfers),
        "transfers_per_iter_max": max(transfers),
        "total_host_transfers": eng.host_transfers,
        "mean_accepted": statistics.fmean(
            s.accepted for s in decode_iters) if decode_iters else 0.0,
        "tokens": sum(len(r.tokens) for r in results),
        "tok_per_s": sum(s.new_tokens for s in decode_iters)
        / max(sum(walls), 1e-9),
        "kv_bytes_reserved": reserved,
        "kv_bytes_resident_peak": resident,
        "token_streams": [r.tokens for r in sorted(results,
                                                   key=lambda r: r.req_id)],
    }
    rep = eng.sanitize_report()
    if rep is not None:
        metrics["sanitize"] = rep.asdict()
    return metrics


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec-len", type=int, default=4)
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="also A/B the mesh-sharded engine on dp*tp forced "
                         "host devices (e.g. 1,8)")
    ap.add_argument("--kv", choices=("dense", "paged"), default="dense",
                    help="'paged' A/Bs the dense vs paged KV layout "
                         "(throughput + resident KV bytes, equal context "
                         "bounds, token-identity gate) AND the paged "
                         "speculative engine's XLA-gather vs windowed "
                         "Pallas kernel routes; merges 'paged' + "
                         "'paged_spec_attn_pim' sections into the "
                         "existing BENCH_engine.json")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--long-prompt", action="store_true",
                    help="A/B chunked admission (8-token prefill window, "
                         "long prompts chunk through it) against a one-shot "
                         "window wide enough for every prompt; merges a "
                         "'long_prompt' section into --out and exits 1 if "
                         "any token stream differs (short prompts included "
                         "— they must be bit-identical to the pre-chunking "
                         "path)")
    ap.add_argument("--pressure", action="store_true",
                    help="oversubscribed paged trace (pool holds ~1/6 of "
                         "the requested pages, preemption enabled) vs the "
                         "unconstrained dense reference; merges a "
                         "'pressure' section into --out and exits 1 unless "
                         "every request completes with its reference token "
                         "stream (never-preempted AND preempted)")
    ap.add_argument("--arrivals", type=float, default=None, metavar="RATE",
                    help="drive the continuous-batching serve() loop with a "
                         "seeded Poisson arrival schedule (RATE requests "
                         "per iteration) across greedy/speculative x "
                         "dense/paged; gates streamed-token identity vs the "
                         "offline oracle and records queue-delay/TTFT/TPOT "
                         "p50/p99; merges an 'arrivals' section into --out")
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="(with --arrivals) re-run the spec_dense combo "
                         "under a live Tracer, write the Chrome trace to "
                         "PATH, and merge a 'telemetry' section (traced vs "
                         "untraced throughput + bit-identity) into --out")
    ap.add_argument("--crash-recovery", action="store_true",
                    help="durability gate: run greedy/speculative x "
                         "dense/paged against a write-ahead journal, kill "
                         "each with the deterministic 'crash' fault at "
                         "several iterations, restore() a fresh engine from "
                         "the journal, and require the union of pre/post-"
                         "crash streams to match the uninterrupted oracle "
                         "exactly-once; merges a 'recovery' section into "
                         "--out and exits 1 on any divergence")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the plain-fused and speculative-fused engines "
                         "under the runtime sanitizer (transfer-guard allow-"
                         "scopes, rank-promotion-raise, per-iteration "
                         "transfer budget, zero-steady-state-recompile "
                         "census); merges a 'sanitize' section into --out "
                         "and exits 1 on any SanitizeError")
    ap.add_argument("--out", type=str, default=str(ROOT / "BENCH_engine.json"))
    args = ap.parse_args()

    if args.trace is not None and args.arrivals is None:
        print("--trace composes with --arrivals only (the telemetry A/B "
              "rides the continuous-batching trace)")
        return 2

    if sum((bool(args.mesh), args.kv == "paged", args.long_prompt,
            args.pressure, args.arrivals is not None,
            args.sanitize, args.crash_recovery)) > 1:
        # each mode is its own early-returning A/B section; combining them
        # would silently skip the other mode's identity gate
        print("--mesh / --kv paged / --long-prompt / --pressure / --arrivals "
              "/ --sanitize / --crash-recovery are separate A/B modes: run "
              "one per invocation (each merges its own section into --out)")
        return 2

    # mesh sizing must precede the first jax backend touch
    from repro.launch.mesh import (force_host_device_count, make_serving_mesh,
                                   parse_mesh)
    mesh_shape = parse_mesh(args.mesh) if args.mesh else None
    if mesh_shape is not None:
        force_host_device_count(mesh_shape[0] * mesh_shape[1])

    import jax

    from repro.configs import get_config
    from repro.models import init_params

    if mesh_shape is not None:
        # validate BEFORE any measurement so a short device count can't
        # waste the whole run
        dp, tp = mesh_shape
        if len(jax.devices()) < dp * tp:
            print(f"--mesh {dp},{tp} needs {dp * tp} devices, have "
                  f"{len(jax.devices())} (is xla_force_host_platform_"
                  "device_count already set lower in XLA_FLAGS?)")
            return 1

    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    draft_params = init_params(cfg, jax.random.PRNGKey(9))

    if args.sanitize:
        # Sanitized smoke: the plain and speculative fused engines under
        # the runtime gates.  A SanitizeError (budget overrun, steady-state
        # retrace, guarded transfer, implicit rank promotion) exits 1; the
        # recorded section lets check_bench re-verify the budget numbers.
        from repro.debug import SanitizeError
        section = {}
        try:
            for mode, spec_len in (("plain_fused", 1),
                                   ("spec_fused", args.spec_len)):
                r = run_engine(cfg, params, draft_params,
                               fused=True, spec_len=spec_len, sanitize=True)
                section[mode] = r["sanitize"]
        except SanitizeError as exc:
            print(f"sanitize FAILED: {exc}")
            return 1
        out = Path(args.out)
        results = json.loads(out.read_text()) if out.exists() else {}
        results["sanitize"] = section
        out.write_text(json.dumps(results, indent=2) + "\n")
        for mode, rep in section.items():
            print(f"sanitize {mode}: {rep['steady_iterations']}/"
                  f"{rep['iterations']} steady iterations at "
                  f"{rep['transfers_per_steady_iter']:.2f} transfers/iter "
                  f"(budget {rep['transfer_budget']}), {rep['programs']} "
                  f"programs, {rep['recompiles']} steady-state recompiles")
        print(f"wrote {out}")
        return 0

    if args.crash_recovery:
        # Durability gate: crash each serving combo mid-trace at several
        # iterations k (deterministic `crash` fault), restore a FRESH
        # engine from the write-ahead journal, and require the union of
        # pre-crash durable finishes (reconstructed from the journal
        # alone) + post-crash results to cover every request exactly once,
        # bit-identical to the uninterrupted oracle.
        import tempfile

        from repro.serving import (EngineCrashError, FaultInjector,
                                   PapiEngine, ServeRequest, recover)

        eos = cfg.vocab_size - 1      # never fires with random-init weights
        crash_points = (2, 6, 11)
        n_requests = 5

        def build(spec_len, paged, submit=True, **kw):
            d = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                     alpha=6.0, eos_token=eos, spec_len=1,
                     debug_invariants=True)
            if spec_len > 1:
                d.update(spec_len=spec_len, draft=(cfg, draft_params))
            if paged:
                d.update(kv_layout="paged", page_size=args.page_size)
            d.update(kw)
            eng = PapiEngine(cfg, params, **d)
            if submit:
                for i in range(n_requests):
                    eng.submit(ServeRequest(i, [3 + i, 5, 7],
                                            max_new_tokens=8 + 2 * i))
            return eng

        section = {"crash_points": list(crash_points), "modes": {}}
        failures: list[str] = []
        combos = {"greedy_dense": (1, False),
                  "spec_dense": (args.spec_len, False),
                  "greedy_paged": (1, True),
                  "spec_paged": (args.spec_len, True)}
        with tempfile.TemporaryDirectory() as td:
            for name, (spec_len, paged) in combos.items():
                oracle_eng = build(spec_len, paged)
                oracle = {r.req_id: r.tokens
                          for r in oracle_eng.run(max_iterations=400)}
                dup_total = resumed_total = torn_total = 0
                completed = identical = True
                for k in crash_points:
                    wal = str(Path(td) / f"{name}_{k}.wal")
                    eng = build(spec_len, paged, journal=wal,
                                faults=FaultInjector(seed=0, crash_p=1.0,
                                                     start=k, stop=k + 1))
                    try:
                        eng.run(max_iterations=400)
                        failures.append(
                            f"{name} k={k}: crash fault never fired")
                        continue
                    except EngineCrashError:
                        pass
                    # pre-crash durable finishes, from the journal ALONE
                    durable = {rid: f.tokens for rid, f in
                               recover(wal, eos_token=eos).finished.items()}
                    fresh = build(spec_len, paged, submit=False, journal=wal)
                    info = fresh.restore(wal)
                    resumed_total += info["resumed"]
                    torn_total += info["torn_bytes"]
                    after = {r.req_id: r.tokens
                             for r in fresh.run(max_iterations=400)}
                    dups = sorted(set(durable) & set(after))
                    dup_total += len(dups)
                    if dups:
                        failures.append(f"{name} k={k}: duplicate finishes "
                                        f"for req(s) {dups}")
                    union = dict(durable)
                    union.update(after)
                    if set(union) != set(oracle):
                        completed = False
                        failures.append(
                            f"{name} k={k}: lost request(s) "
                            f"{sorted(set(oracle) - set(union))}")
                    elif union != oracle:
                        identical = False
                        bad = sorted(r for r in oracle
                                     if union[r] != oracle[r])
                        failures.append(f"{name} k={k}: stream(s) diverged "
                                        f"from oracle for req(s) {bad}")
                section["modes"][name] = {
                    "requests": n_requests,
                    "completed": completed,
                    "duplicate_finishes": dup_total,
                    "tokens_bit_identical": identical and completed,
                    "resumed_requests_total": resumed_total,
                    "torn_bytes_total": torn_total,
                }
                print(f"crash-recovery {name}: crashes at {crash_points}, "
                      f"{resumed_total} resumed, {dup_total} duplicate "
                      f"finishes, union identical: "
                      f"{identical and completed}")
        out = Path(args.out)
        results = json.loads(out.read_text()) if out.exists() else {}
        results["recovery"] = section
        out.write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {out}")
        if failures:
            for f in failures:
                print(f"crash-recovery FAILED: {f}")
            return 1
        return 0

    if args.long_prompt:
        # Chunked-prefill A/B: the SAME engine code with an 8-token window
        # (prompts >= 4x the window chunk through `models.prefill_chunk`)
        # vs a 128-token window (every prompt one-shot = the pre-chunking
        # admission path).  Request 0 is a short (<= window) prompt, so the
        # gate covers BOTH acceptance clauses: long prompts complete
        # untruncated AND short prompts stay bit-identical to the
        # pre-chunking engine.  Exits 1 on any stream divergence.
        vocab = cfg.vocab_size
        def prompt_fn(i):
            if i == 0:
                return [3, 5, 7]
            return [3 + (7 * i + j) % (vocab - 3) for j in range(32 + 4 * i)]
        eos = vocab - 1               # never fires with random-init weights
        common = dict(fused=True, spec_len=1, n_requests=5, max_new=12,
                      eos_token=eos, cache_capacity=256, prompt_fn=prompt_fn)
        chunked = run_engine(cfg, params, draft_params, prefill_len=8,
                             **common)
        oneshot = run_engine(cfg, params, draft_params, prefill_len=128,
                             **common)
        identical = chunked["token_streams"] == oneshot["token_streams"]
        longest = max(len(prompt_fn(i)) for i in range(5))
        section = {
            "window_chunked": 8,
            "window_oneshot": 128,
            "longest_prompt": longest,
            "chunked_tok_per_s": chunked["tok_per_s"],
            "oneshot_tok_per_s": oneshot["tok_per_s"],
            "tokens_bit_identical": identical,
        }
        out = Path(args.out)
        results = json.loads(out.read_text()) if out.exists() else {}
        results["long_prompt"] = section
        out.write_text(json.dumps(results, indent=2) + "\n")
        print(f"long-prompt (<= {longest} tokens through an 8-token "
              f"window): {chunked['tok_per_s']:.1f} tok/s chunked vs "
              f"{oneshot['tok_per_s']:.1f} tok/s one-shot, tokens "
              f"identical: {identical}")
        print(f"wrote {out}")
        if not identical:
            print("WARNING: chunked admission diverged from the one-shot "
                  "prefill token streams")
            return 1
        return 0

    if args.pressure:
        # Oversubscribed serving: 12 requests whose page budgets total 6x
        # the pool.  The pre-preemption engine deferred the queue head
        # indefinitely while two long-running requests held the pool; with
        # pool-pressure preemption the head is admitted within
        # `preempt_after` iterations of its first deferral, every request
        # completes, and the streams stay bit-identical to the
        # unconstrained dense reference — preempted requests included
        # (their requeue recomputes prompt + tokens-so-far through chunked
        # prefill).  First-admission delay (admit iteration - submit
        # iteration) is the bounded-wait metric check_bench gates.
        from repro.serving import PapiEngine, ServeRequest
        eos = cfg.vocab_size - 1      # never fires with random-init weights
        reqs = [([3 + i, 5, 7], 20) for i in range(12)]

        def serve(**kw):
            eng = PapiEngine(cfg, params, max_slots=4, prefill_len=8,
                             alpha=6.0, eos_token=eos, fused=True, **kw)
            for i, (prompt, n) in enumerate(reqs):
                eng.submit(ServeRequest(i, list(prompt), max_new_tokens=n))
            return {r.req_id: r for r in eng.run(max_iterations=2000)}, eng

        want, _ = serve(cache_capacity=64)
        got, eng = serve(cache_capacity=16, kv_layout="paged", page_size=4,
                         preempt_after=3, debug_invariants=True)

        completed = sum(r.finished_reason == "length" and len(r.tokens) == 20
                        for r in got.values())
        never = [i for i in got if i not in eng.preempted_ids]
        never_ok = all(got[i].tokens == want[i].tokens for i in never)
        preempted_ok = all(got[i].tokens == want[i].tokens
                           for i in eng.preempted_ids)
        delays = sorted(eng.admit_iteration[i] - eng.submit_iteration[i]
                        for i in got)
        pct = lambda q: delays[min(len(delays) - 1,
                                   int(q * (len(delays) - 1) + 0.999))]
        section = {
            "requests": len(reqs),
            "pool_pages": eng.kv.alloc.num_pages,
            "pages_demanded": len(reqs) * eng.kv.pages_for(3 + 20 + 1),
            "preempt_after": 3,
            "preemptions": eng.preemptions,
            "completed": completed,
            "iterations": eng.iteration,
            "admission_delay_p50": pct(0.50),
            "admission_delay_p99": pct(0.99),
            "admission_delay_max": delays[-1],
            # the check_bench-gated flag: never-preempted requests match
            # the unconstrained reference bit for bit
            "tokens_bit_identical": never_ok,
            "preempted_tokens_bit_identical": preempted_ok,
        }
        out = Path(args.out)
        results = json.loads(out.read_text()) if out.exists() else {}
        results["pressure"] = section
        out.write_text(json.dumps(results, indent=2) + "\n")
        print(f"pressure: {completed}/{len(reqs)} completed over a "
              f"{section['pages_demanded']}/{section['pool_pages']}-page "
              f"oversubscription, {eng.preemptions} preemptions, admission "
              f"delay p50/p99/max = {pct(0.5)}/{pct(0.99)}/{delays[-1]} "
              f"iterations, identical (never-preempted/preempted): "
              f"{never_ok}/{preempted_ok}")
        print(f"wrote {out}")
        if completed < len(reqs) or not (never_ok and preempted_ok):
            print("WARNING: oversubscribed trace lost requests or diverged "
                  "from the reference streams")
            return 1
        return 0

    if args.arrivals is not None:
        # Continuous-batching acceptance: a seeded Poisson arrival trace
        # through `serve()` must stream, for every combo the engine serves
        # (greedy/speculative x dense/paged), exactly the tokens the offline
        # batch oracle commits — live admission, mixed prefill/decode waves,
        # and the latency bookkeeping must be invisible to the streams.
        # Iteration-valued queue-delay/TTFT percentiles are deterministic
        # for the fixed seed (check_bench gates the p99 TTFT bound);
        # wall-clock TTFT/TPOT ride along for the perf trajectory.
        import numpy as np

        from repro.serving import PapiEngine, ServeRequest, latency_summary
        rate = args.arrivals
        if rate <= 0:
            print("--arrivals RATE must be > 0")
            return 2
        eos = cfg.vocab_size - 1      # never fires with random-init weights
        rng = np.random.default_rng(0)
        n_req = 10
        prompts = [[int(t) for t in
                    rng.integers(3, cfg.vocab_size - 1, int(rng.integers(3, 28)))]
                   for _ in range(n_req)]
        budgets = [int(rng.integers(4, 14)) for _ in range(n_req)]
        # Poisson process: exponential inter-arrival gaps, floored to the
        # engine's iteration clock (the serve loop polls once per iteration)
        arrive = np.cumsum(np.floor(
            rng.exponential(1.0 / rate, n_req)).astype(int))

        def requests():
            return [ServeRequest(i, list(prompts[i]),
                                 max_new_tokens=budgets[i])
                    for i in range(n_req)]

        def schedule():
            sched = [[] for _ in range(int(arrive[-1]) + 1)]
            for i, it in enumerate(arrive):
                sched[int(it)].append(ServeRequest(i, list(prompts[i]),
                                                   max_new_tokens=budgets[i]))
            return sched

        def engine(**kw):
            return PapiEngine(cfg, params, max_slots=4, cache_capacity=64,
                              prefill_len=8, alpha=6.0, eos_token=eos,
                              fused=True, debug_invariants=True, **kw)

        combos = [
            ("greedy_dense", {}),
            ("greedy_paged", dict(kv_layout="paged", page_size=args.page_size,
                                  max_blocks=64 // args.page_size)),
            ("spec_dense", dict(spec_len=args.spec_len,
                                draft=(cfg, draft_params))),
            ("spec_paged", dict(spec_len=args.spec_len,
                                draft=(cfg, draft_params),
                                kv_layout="paged", page_size=args.page_size,
                                max_blocks=64 // args.page_size)),
        ]
        section = {"rate": rate, "requests": n_req,
                   "arrival_span_iters": int(arrive[-1]), "modes": {}}
        all_ok = True
        spec_dense_ref = None     # (kw, oracle streams, live streams, engine)
        for label, kw in combos:
            oracle = engine(**kw)
            for r in requests():
                oracle.submit(r)
            want = {r.req_id: r.tokens
                    for r in oracle.run(max_iterations=2000)}

            eng = engine(**kw)
            streams, finals = {}, {}
            for ev in eng.serve(schedule()):
                if ev.finished:
                    finals[ev.req_id] = ev.result
                else:
                    streams.setdefault(ev.req_id, []).append(ev.token)
            live = {rid: res.tokens for rid, res in finals.items()}
            streamed_ok = all(streams.get(rid, []) == res.tokens
                              for rid, res in finals.items())
            same = live == want and streamed_ok
            completed = len(finals)
            summ = latency_summary(finals.values())
            section["modes"][label] = {
                "completed": completed,
                "tokens_bit_identical": same,
                "iterations": eng.iteration,
                "queue_delay_iters_p50": summ["queue_delay_iters"]["p50"],
                "queue_delay_iters_p99": summ["queue_delay_iters"]["p99"],
                "ttft_iters_p50": summ["ttft_iters"]["p50"],
                "ttft_iters_p99": summ["ttft_iters"]["p99"],
                "ttft_s_p50": summ["ttft_s"]["p50"],
                "ttft_s_p99": summ["ttft_s"]["p99"],
                "tpot_s_p50": summ["tpot_s"]["p50"],
                "tpot_s_p99": summ["tpot_s"]["p99"],
            }
            all_ok = all_ok and same and completed == n_req
            if label == "spec_dense":
                spec_dense_ref = (kw, want, live, eng)
            print(f"{label}: {completed}/{n_req} completed in "
                  f"{eng.iteration} iterations, ttft p50/p99 = "
                  f"{summ['ttft_iters']['p50']:.0f}/"
                  f"{summ['ttft_iters']['p99']:.0f} iters "
                  f"({summ['ttft_s']['p99'] * 1e3:.0f}ms p99), tpot p99 = "
                  f"{summ['tpot_s']['p99'] * 1e3:.1f}ms, tokens identical: "
                  f"{same}")

        # Telemetry overhead A/B: the SAME spec_dense arrival trace once
        # more, now with a live Tracer (per-program timed_call blocks on
        # every dispatch), against the untraced run already measured above.
        # Identity proves observation never perturbs tokens; the median
        # per-iteration wall ratio is the overhead check_bench gates.
        telemetry = None
        if args.trace is not None:
            from repro.serving import Tracer, write_trace
            kw, want_sd, live_sd, eng_un = spec_dense_ref
            tracer = Tracer()
            eng_tr = engine(tracer=tracer, **kw)
            finals_tr = {}
            for ev in eng_tr.serve(schedule()):
                if ev.finished:
                    finals_tr[ev.req_id] = ev.result
            live_tr = {rid: res.tokens for rid, res in finals_tr.items()}
            t_same = live_tr == want_sd and live_tr == live_sd
            write_trace(tracer, args.trace, "chrome")

            def decode_walls(e):
                its = ([s for s in e.stats[2:] if s.new_tokens > 0]
                       or [s for s in e.stats if s.new_tokens > 0])
                walls = [s.wall_s for s in its]
                return (sum(s.new_tokens for s in its)
                        / max(sum(walls), 1e-9), statistics.median(walls))

            un_tps, un_med = decode_walls(eng_un)
            tr_tps, tr_med = decode_walls(eng_tr)
            # Overhead estimator: both runs execute the SAME deterministic
            # iteration sequence (identical tokens), so pair iterations by
            # index and take the median of per-iteration wall RATIOS —
            # workload variation cancels pairwise and a compile/GC spike in
            # either run is a single outlier ratio the median discards
            # (a ratio of unpaired medians flaked at ±7% on shared runners).
            pairs = [(u.wall_s, t.wall_s)
                     for u, t in zip(eng_un.stats[2:], eng_tr.stats[2:])
                     if u.new_tokens > 0 and t.new_tokens > 0]
            overhead = (statistics.median(t / u for u, t in pairs) - 1.0
                        if pairs else tr_med / un_med - 1.0)
            telemetry = {
                "mode": "spec_dense",
                "untraced_tok_per_s": un_tps,
                "traced_tok_per_s": tr_tps,
                "untraced_wall_s_per_iter_median": un_med,
                "traced_wall_s_per_iter_median": tr_med,
                "overhead_frac": overhead,
                "tokens_bit_identical": t_same,
                "events": tracer.emitted,
                "events_dropped": tracer.dropped,
                "program_keys": len(tracer.programs),
                "trace_file": str(args.trace),
            }
            all_ok = all_ok and t_same
            print(f"telemetry: {un_tps:.1f} tok/s untraced vs "
                  f"{tr_tps:.1f} tok/s traced (median-wall overhead "
                  f"{telemetry['overhead_frac']:+.1%}), {tracer.emitted} "
                  f"events, {len(tracer.programs)} program keys, tokens "
                  f"identical: {t_same}")
            print(f"wrote {args.trace}")

        out = Path(args.out)
        results = json.loads(out.read_text()) if out.exists() else {}
        results["arrivals"] = section
        if telemetry is not None:
            results["telemetry"] = telemetry
        out.write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {out}")
        if not all_ok:
            print("WARNING: streamed tokens diverged from the offline "
                  "oracle or requests were lost under live arrivals")
            return 1
        return 0

    if args.kv == "paged":
        # Paged mode A/Bs dense-vs-paged (greedy + speculative, mixed
        # request lengths so admission/growth/rewind all run) and MERGES a
        # "paged" section into the tracked BENCH_engine.json — the
        # fused/legacy baselines are not remeasured.  Exit 1 if the paged
        # token streams diverge from the dense engine's (same gate as
        # --mesh): the layout must change memory economics, never tokens.
        # Both sides bound per-request context at the dense slab (64
        # tokens): max_blocks caps the table width so the XLA decode path
        # gathers a 64-token view, not the whole pool (see module
        # docstring).
        ragged = lambda i: 8 + 5 * i
        eos = cfg.vocab_size - 1      # never fires with random-init weights
        cache_capacity = 64           # the dense slab = the context bound
        common = dict(fused=True, max_new_fn=ragged, eos_token=eos,
                      cache_capacity=cache_capacity)
        max_blocks = max(cache_capacity // args.page_size, 1)
        paged_kw = dict(kv_layout="paged", page_size=args.page_size,
                        max_blocks=max_blocks)
        section = {"page_size": args.page_size, "max_blocks": max_blocks,
                   "modes": {}}
        identical = True
        dense_streams = gather = None
        for label, spec in (("plain", 1), ("speculative", args.spec_len)):
            dense = run_engine(cfg, params, draft_params, spec_len=spec,
                               **common)
            paged = run_engine(cfg, params, draft_params, spec_len=spec,
                               **common, **paged_kw)
            same = paged["token_streams"] == dense["token_streams"]
            identical = identical and same
            if label == "speculative":
                dense_streams = dense["token_streams"]
                gather = paged    # the XLA-gather side of the pim A/B below
            section["modes"][label] = {
                "dense_tok_per_s": dense["tok_per_s"],
                "paged_tok_per_s": paged["tok_per_s"],
                "dense_kv_bytes_resident": dense["kv_bytes_resident_peak"],
                "paged_kv_bytes_resident": paged["kv_bytes_resident_peak"],
                "paged_kv_bytes_reserved": paged["kv_bytes_reserved"],
                "tokens_bit_identical": same,
            }
            print(f"{label}: {dense['tok_per_s']:.1f} tok/s dense vs "
                  f"{paged['tok_per_s']:.1f} tok/s paged; resident KV "
                  f"{dense['kv_bytes_resident_peak'] / 1e6:.2f}MB -> "
                  f"{paged['kv_bytes_resident_peak'] / 1e6:.2f}MB, "
                  f"tokens identical: {same}")

        # Same run, second A/B: the paged SPECULATIVE engine's two attention
        # routes — XLA page-gather (the loop's paged speculative run,
        # reused, not remeasured) vs the windowed block-table Pallas kernel
        # (attn_pim=True: k draft steps + the TLP=k verify window all
        # resolve pages inside the index_map, gather_kv_pages never
        # traces).  Identity gated against BOTH the XLA-path paged engine
        # and the dense engine.  On CPU the kernel runs interpreted, so the
        # delta measures interpret overhead (see module docstring).
        kernel = run_engine(cfg, params, draft_params,
                            spec_len=args.spec_len, **common, **paged_kw,
                            attn_pim=True)
        pim_same = (kernel["token_streams"] == gather["token_streams"]
                    and kernel["token_streams"] == dense_streams)
        identical = identical and pim_same
        results_key = {
            "spec_len": args.spec_len,
            "page_size": args.page_size,
            "max_blocks": max_blocks,
            "xla_gather_tok_per_s": gather["tok_per_s"],
            "attn_pim_kernel_tok_per_s": kernel["tok_per_s"],
            "backend": jax.default_backend(),
            "kernel_interpreted": jax.default_backend() != "tpu",
            "tokens_bit_identical": pim_same,
        }
        print(f"paged_spec_attn_pim: {gather['tok_per_s']:.1f} tok/s "
              f"XLA-gather vs {kernel['tok_per_s']:.1f} tok/s windowed "
              f"kernel, tokens identical: {pim_same}")

        out = Path(args.out)
        results = json.loads(out.read_text()) if out.exists() else {}
        results["paged"] = section
        results["paged_spec_attn_pim"] = results_key
        out.write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {out}")
        if not identical:
            print("WARNING: paged engine diverged from the dense token "
                  "streams")
            return 1
        return 0

    if mesh_shape is not None:
        # Mesh mode measures ONLY the unsharded-vs-sharded engine A/B —
        # both under the same forced-device environment, apples to apples —
        # and merges the section into an existing BENCH_engine.json, so the
        # tracked fused/legacy baselines stay genuine 1-device numbers
        # (forced host devices timeshare the cores and would inflate them).
        dp, tp = mesh_shape
        mesh = make_serving_mesh(dp, tp)
        single = run_engine(cfg, params, draft_params,
                            fused=True, spec_len=1)
        sharded = run_engine(cfg, params, draft_params,
                             fused=True, spec_len=1, mesh=mesh)
        section = {
            "mesh": {"data": dp, "model": tp},
            "devices": len(jax.devices()),
            "one_device_tok_per_s": single["tok_per_s"],
            "mesh_tok_per_s": sharded["tok_per_s"],
            "tokens_bit_identical":
                sharded["token_streams"] == single["token_streams"],
        }
        out = Path(args.out)
        results = json.loads(out.read_text()) if out.exists() else {}
        results["sharded"] = section
        out.write_text(json.dumps(results, indent=2) + "\n")
        print(f"mesh {dp}x{tp}: {single['tok_per_s']:.1f} tok/s (unsharded) "
              f"vs {sharded['tok_per_s']:.1f} tok/s (sharded), "
              f"tokens identical: {section['tokens_bit_identical']}")
        print(f"wrote {out}")
        if not section["tokens_bit_identical"]:
            print("WARNING: sharded engine diverged from the unsharded "
                  "token streams")
            return 1
        return 0

    results = {
        "backend": jax.default_backend(),
        "model": cfg.name,
        "plain": {
            "fused": run_engine(cfg, params, draft_params,
                                fused=True, spec_len=1),
            "legacy": run_engine(cfg, params, draft_params,
                                 fused=False, spec_len=1),
        },
        "speculative": {
            "fused": run_engine(cfg, params, draft_params,
                                fused=True, spec_len=args.spec_len),
            "legacy": run_engine(cfg, params, draft_params,
                                 fused=False, spec_len=args.spec_len),
        },
    }
    spec_f = results["speculative"]["fused"]
    spec_l = results["speculative"]["legacy"]
    results["summary"] = {
        "spec_transfer_reduction":
            spec_l["transfers_per_iter_mean"] / spec_f["transfers_per_iter_mean"],
        "spec_wall_speedup":
            spec_l["wall_s_per_iter_median"] / spec_f["wall_s_per_iter_median"],
        "plain_transfer_reduction":
            results["plain"]["legacy"]["transfers_per_iter_mean"]
            / results["plain"]["fused"]["transfers_per_iter_mean"],
    }

    # token streams feed the mesh-mode A/B; keep the JSON to the metrics
    for section in (results["plain"], results["speculative"]):
        for r in section.values():
            r.pop("token_streams", None)

    Path(args.out).write_text(json.dumps(results, indent=2) + "\n")
    s = results["summary"]
    print(f"spec_len={args.spec_len}: "
          f"transfers/iter {spec_l['transfers_per_iter_mean']:.2f} -> "
          f"{spec_f['transfers_per_iter_mean']:.2f} "
          f"({s['spec_transfer_reduction']:.1f}x reduction), "
          f"wall/iter {spec_l['wall_s_per_iter_median']*1e3:.1f}ms -> "
          f"{spec_f['wall_s_per_iter_median']*1e3:.1f}ms "
          f"({s['spec_wall_speedup']:.2f}x)")
    print(f"wrote {args.out}")
    ok = s["spec_transfer_reduction"] >= 2.0
    if not ok:
        print("WARNING: transfer reduction below the 2x acceptance bar")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
