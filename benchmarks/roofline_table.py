"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_table [dir] [--markdown]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirname: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def markdown(rows: list[dict]) -> str:
    out = ["| arch | cell | mesh | compute s | memory s | collective s | "
           "bottleneck | useful | roofline | GB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r["roofline"]
        ma = r["memory_analysis"].get("live_bytes_per_device", 0) or 0
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['compute_s']:.2e} | {rl['memory_s']:.2e} "
            f"| {rl['collective_s']:.2e} | {rl['bottleneck']} "
            f"| {rl['useful_fraction']:.3f} "
            f"| {100 * rl['roofline_fraction']:.2f}% | {ma / 1e9:.2f} |")
    return "\n".join(out)


def csv(rows: list[dict]) -> str:
    out = ["arch,cell,mesh,compute_s,memory_s,collective_s,bottleneck,"
           "useful_fraction,roofline_fraction,live_gb_per_dev,compile_s"]
    for r in rows:
        rl = r["roofline"]
        ma = r["memory_analysis"].get("live_bytes_per_device", 0) or 0
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{rl['compute_s']:.4e},"
            f"{rl['memory_s']:.4e},{rl['collective_s']:.4e},"
            f"{rl['bottleneck']},{rl['useful_fraction']:.4f},"
            f"{rl['roofline_fraction']:.5f},{ma / 1e9:.2f},{r['compile_s']}")
    return "\n".join(out)


def main() -> None:
    dirname = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(dirname)
    if "--markdown" in sys.argv:
        print(markdown(rows))
    else:
        print(csv(rows))


if __name__ == "__main__":
    main()
