"""Fig. 9 — end-to-end speedup/energy on the Dolly general-qa trace,
GPT-3 175B.  Paper: 1.7x / 1.7x / 8.1x (lower than creative-writing because
shorter outputs => less decode dominance + weaker RLP decay)."""
import numpy as np

from repro.configs.paper_models import GPT3_175B
from repro.core.system import compare_systems, simulate_prefill_gpu
from repro.core.traces import generate_trace


def rows():
    qa = generate_trace("general-qa", 64, seed=1)
    cw = generate_trace("creative-writing", 64, seed=0)
    out = []
    speed = {"a100_attacc": [], "a100_hbmpim": [], "attacc_only": []}
    espd = []
    for bs, sl in [(b, s) for b in (4, 16, 64) for s in (1, 2, 4)]:
        res = compare_systems(GPT3_175B, qa[:bs], bs, sl)
        prefill = simulate_prefill_gpu(GPT3_175B, qa[:bs])
        papi = res["papi"].time_s + prefill
        for s in speed:
            speed[s].append((res[s].time_s + prefill) / papi)
        espd.append(res["a100_attacc"].energy_per_token
                    / res["papi"].energy_per_token)
    for s, v in speed.items():
        paper = {"a100_attacc": 1.7, "a100_hbmpim": 1.7,
                 "attacc_only": 8.1}[s]
        out.append((f"fig9_MEAN_speedup_vs_{s}_qa", float(np.mean(v)),
                    f"paper={paper} (e2e incl. prefill)"))
    out.append(("fig9_MEAN_energy_eff_qa", float(np.mean(espd)), "paper=3.1"))

    # the paper's explanation: qa speedups < creative-writing speedups
    cw_res = compare_systems(LLAMA := GPT3_175B, cw[:16], 16, 2)
    qa_res = compare_systems(GPT3_175B, qa[:16], 16, 2)
    cw_ratio = cw_res["a100_attacc"].time_s / cw_res["papi"].time_s
    qa_pref = simulate_prefill_gpu(GPT3_175B, qa[:16])
    qa_ratio = ((qa_res["a100_attacc"].time_s + qa_pref)
                / (qa_res["papi"].time_s + qa_pref))
    out.append(("fig9_qa_lower_than_cw", float(cw_ratio > qa_ratio),
                f"cw={cw_ratio:.2f} qa={qa_ratio:.2f}"))
    return out
