"""Fig. 7 — PIM energy breakdown vs data reuse + power vs reuse per xPyB
config.  (a) 96.7% DRAM at reuse=1; (b) 33.1% at reuse=64; (c) power curves
against the 116 W HBM budget."""
from repro.core import pim


def rows():
    out = []
    for reuse in (1, 64):
        eb = pim.energy_breakdown(reuse)
        for k, v in eb.items():
            paper = {"1dram": 0.967, "64dram": 0.331}.get(f"{reuse}{k}")
            out.append((f"fig7_energy_frac_{k}_reuse{reuse}", v,
                        f"paper={paper}" if paper else ""))
    for reuse in (1, 2, 4, 8, 16, 64):
        for dev in (pim.ATTACC, pim.HBM_PIM, pim.FC_PIM):
            p = dev.power_at(reuse)
            out.append((f"fig7c_power_{dev.name}_reuse{reuse}", p,
                        "OVER" if p > pim.HBM_POWER_BUDGET_W else "within"))
    out.append(("fig7_power_budget_w", pim.HBM_POWER_BUDGET_W, "HBM3 IDD7"))
    return out
