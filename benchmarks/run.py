"""Benchmark harness: one module per paper figure/table.

Prints ``name,value,derived`` CSV rows; MEAN rows carry the paper's reported
number in the derived column for direct comparison (EXPERIMENTS.md §Repro).
"""
import sys


def main() -> None:
    from benchmarks import (
        fig2_roofline,
        fig4_fc_latency,
        fig6_ai_estimation,
        fig7_energy,
        fig8_e2e,
        fig9_e2e_qa,
        fig10_sensitivity,
        fig11_pim_only,
        fig12_breakdown,
        kernels_micro,
        scheduler_overhead,
    )

    modules = [
        fig2_roofline, fig4_fc_latency, fig6_ai_estimation, fig7_energy,
        fig8_e2e, fig9_e2e_qa, fig10_sensitivity, fig11_pim_only,
        fig12_breakdown, scheduler_overhead, kernels_micro,
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,value,derived")
    for mod in modules:
        if only and only not in mod.__name__:
            continue
        for name, value, derived in mod.rows():
            print(f"{name},{value:.6g},{derived}")


if __name__ == "__main__":
    main()
