"""Fig. 12 — per-token decode time breakdown (LLaMA-65B, batch 4, spec 4):
AttAcc-only vs PIM-only PAPI.  Paper's four observations: FC dominates; the
FC-PIM path is ~2.9x faster on FC; attention is ~1.7x *slower* on Attn-PIM
(1P2B) than AttAcc (1P1B); communication ~28.2% of PIM-only decode time."""
from repro.configs.paper_models import LLAMA_65B
from repro.core.system import simulate_decode
from repro.core.traces import generate_trace


def rows():
    trace = generate_trace("creative-writing", 4, seed=0)
    ao = simulate_decode("attacc_only", LLAMA_65B, trace, 4, 4)
    po = simulate_decode("pim_only_papi", LLAMA_65B, trace, 4, 4)
    out = [
        ("fig12_attacconly_fc_ms_per_iter", 1e3 * ao.fc_time_s / ao.iterations, ""),
        ("fig12_pimonly_fc_ms_per_iter", 1e3 * po.fc_time_s / po.iterations, ""),
        ("fig12_fc_speedup_on_fcpim", ao.fc_time_s / po.fc_time_s,
         "paper=2.9"),
        ("fig12_attn_slowdown_on_attnpim", po.attn_time_s / ao.attn_time_s,
         "paper=1.7 (1P2B has half the FPUs)"),
        ("fig12_pimonly_comm_fraction", po.comm_time_s / po.time_s,
         "paper=0.282"),
        ("fig12_fc_dominates", float(po.fc_time_s > po.attn_time_s), ""),
    ]
    return out
