"""Kernel microbenchmarks: the two FC paths (MXU dot vs fc_gemv) and the
decode-attention / ssd kernels at smoke scale (CPU wall-clock; on TPU the
same harness feeds calibrate_alpha_measured)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import fc_forward


def _bench(fn, *args, reps=5):
    fn(*args).block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def rows():
    out = []
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (1024, 1024), jnp.float32) / 32
    pu = jax.jit(lambda x: fc_forward(x, w, "pu"))
    for m in (1, 8, 64):
        x = jax.random.normal(k, (m, 1024), jnp.float32)
        out.append((f"fc_pu_m{m}_us", _bench(pu, x), "XLA dot (MXU path)"))
    # the pim path (interpret mode on CPU: correctness harness, not perf)
    x = jax.random.normal(k, (8, 1024), jnp.float32)
    t0 = time.perf_counter()
    fc_forward(x, w, "pim", interpret=True).block_until_ready()
    out.append(("fc_pim_m8_interpret_us", (time.perf_counter() - t0) * 1e6,
                "Pallas interpret (CPU validation mode)"))
    return out
