"""Fig. 6 — actual (Eq. 1) vs estimated (Eq. 2) arithmetic intensity for the
GPT-3 66B FC kernel across RLP/TLP, plus the worst-case archs from the
assignment pool (Eq. 2's large-h assumption is weakest at qwen2's h=896)."""
from repro.configs import get_config
from repro.configs.paper_models import GPT3_66B
from repro.core.ai import fc_ai_estimate, fc_ai_exact


def rows():
    out = []
    h = GPT3_66B.d_model
    for rlp in (1, 4, 16, 64, 128):
        for tlp in (1, 4, 8):
            exact = fc_ai_exact(rlp * tlp, h)
            est = fc_ai_estimate(rlp, tlp)
            out.append((f"fig6_ai_rlp{rlp}_tlp{tlp}_exact", exact, ""))
            out.append((f"fig6_ai_rlp{rlp}_tlp{tlp}_est", est,
                        f"rel_err={(est - exact) / exact:.3f}"))
    for arch in ("qwen2-0.5b", "command-r-plus-104b"):
        hh = get_config(arch).d_model
        exact = fc_ai_exact(64, hh)
        out.append((f"fig6_relerr_{arch}_m64",
                    (fc_ai_estimate(64, 1) - exact) / exact,
                    f"h={hh}"))
    return out
