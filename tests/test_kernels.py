"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (the kernel body executes in
Python) — this validates the BlockSpec indexing, scratch accumulation and
online-softmax math that will run compiled on TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.fc_gemv import fc_gemv
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,nkv,g,hd,skv,block_k",
    [
        (2, 2, 4, 64, 256, 128),
        (1, 4, 1, 128, 512, 256),   # MHA (g=1)
        (3, 1, 12, 64, 384, 128),   # extreme GQA, ragged grid
        (2, 2, 7, 128, 256, 256),   # odd group size, single kv block
    ],
)
def test_decode_attention_sweep(b, nkv, g, hd, skv, block_k, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(keys[0], (b, nkv, g, hd), dtype)
    k = jax.random.normal(keys[1], (b, skv, nkv, hd), dtype)
    v = jax.random.normal(keys[2], (b, skv, nkv, hd), dtype)
    lens = jax.random.randint(keys[3], (b,), 1, skv + 1)
    got = decode_attention(q, k, v, lens, block_k=block_k, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_decode_attention_masks_beyond_len():
    """KV positions past lens must not affect the output."""
    b, nkv, g, hd, skv = 1, 2, 4, 64, 256
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (b, nkv, g, hd), jnp.float32)
    k = jax.random.normal(keys[1], (b, skv, nkv, hd), jnp.float32)
    v = jax.random.normal(keys[2], (b, skv, nkv, hd), jnp.float32)
    lens = jnp.array([100], jnp.int32)
    out1 = decode_attention(q, k, v, lens, block_k=128, interpret=True)
    k2 = k.at[:, 100:].set(999.0)
    v2 = v.at[:, 100:].set(-999.0)
    out2 = decode_attention(q, k2, v2, lens, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_block_skip_bit_identical(dtype):
    """Ragged continuous batch: skipping fully-masked KV blocks (clamped
    index_map + pl.when no-op) must be BIT-identical to streaming them all —
    a fully-masked tile contributes exactly alpha=1.0, p=+0.0."""
    b, nkv, g, hd, skv, block_k = 5, 2, 4, 64, 512, 128
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (b, nkv, g, hd), dtype)
    k = jax.random.normal(keys[1], (b, skv, nkv, hd), dtype)
    v = jax.random.normal(keys[2], (b, skv, nkv, hd), dtype)
    # raggedness spanning: sub-block, block-aligned, mid, near-full, full
    lens = jnp.array([1, 128, 200, 511, 512], jnp.int32)
    skip = decode_attention(q, k, v, lens, block_k=block_k, interpret=True,
                            block_skip=True)
    full = decode_attention(q, k, v, lens, block_k=block_k, interpret=True,
                            block_skip=False)
    np.testing.assert_array_equal(
        np.asarray(skip, np.float32), np.asarray(full, np.float32))
    # and both still match the oracle
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(skip, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,nkv,g,hd,skv,block_k,t",
    [
        (2, 2, 4, 64, 256, 128, 2),    # TLP=2 verify window
        (3, 1, 12, 64, 384, 128, 4),   # extreme GQA, spec window
        (1, 4, 1, 128, 512, 256, 3),   # MHA (g=1), odd window
        (2, 2, 7, 128, 256, 256, 8),   # chunk-wave-sized window, odd group
    ],
)
def test_decode_attention_windowed_sweep(b, nkv, g, hd, skv, block_k, t,
                                         dtype):
    """Query windows (TLP>1): the kernel's intra-window causal mask vs the
    pure-jnp oracle, across GQA ratios and ragged per-request lengths."""
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    q = jax.random.normal(keys[0], (b, nkv, t * g, hd), dtype)
    k = jax.random.normal(keys[1], (b, skv, nkv, hd), dtype)
    v = jax.random.normal(keys[2], (b, skv, nkv, hd), dtype)
    # lens >= t: every window row keeps at least its own diagonal position
    lens = jax.random.randint(keys[3], (b,), t, skv + 1)
    got = decode_attention(q, k, v, lens, block_k=block_k, interpret=True,
                           q_rows=t)
    want = ref.decode_attention_window_ref(q, k, v, lens, q_rows=t)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


def test_decode_attention_windowed_causal_within_window():
    """Row r must not see KV written for later window rows: perturbing KV at
    positions past row r's own leaves rows 0..r bit-unchanged."""
    b, nkv, g, hd, skv, t = 1, 2, 2, 64, 256, 4
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(keys[0], (b, nkv, t * g, hd), jnp.float32)
    k = jax.random.normal(keys[1], (b, skv, nkv, hd), jnp.float32)
    v = jax.random.normal(keys[2], (b, skv, nkv, hd), jnp.float32)
    lens = jnp.array([100], jnp.int32)        # window rows at 96..99
    out = decode_attention(q, k, v, lens, block_k=128, interpret=True,
                           q_rows=t)
    for r in range(t):
        pos_r = 100 - t + r                   # row r's absolute position
        k2 = k.at[:, pos_r + 1:].set(999.0)
        v2 = v.at[:, pos_r + 1:].set(-999.0)
        out2 = decode_attention(q, k2, v2, lens, block_k=128, interpret=True,
                                q_rows=t)
        # rows 0..r (kernel rows 0..(r+1)*g-1) see nothing past pos_r
        np.testing.assert_array_equal(
            np.asarray(out[:, :, : (r + 1) * g]),
            np.asarray(out2[:, :, : (r + 1) * g]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_windowed_block_skip_bit_identical(dtype):
    """Ragged block skipping must stay bit-exact for query windows: the
    clamp keys on the full window length, and fully-masked tiles contribute
    exactly nothing to every row."""
    b, nkv, g, hd, skv, block_k, t = 5, 2, 4, 64, 512, 128, 3
    keys = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(keys[0], (b, nkv, t * g, hd), dtype)
    k = jax.random.normal(keys[1], (b, skv, nkv, hd), dtype)
    v = jax.random.normal(keys[2], (b, skv, nkv, hd), dtype)
    lens = jnp.array([3, 128, 200, 511, 512], jnp.int32)
    skip = decode_attention(q, k, v, lens, block_k=block_k, interpret=True,
                            block_skip=True, q_rows=t)
    full = decode_attention(q, k, v, lens, block_k=block_k, interpret=True,
                            block_skip=False, q_rows=t)
    np.testing.assert_array_equal(
        np.asarray(skip, np.float32), np.asarray(full, np.float32))
    want = ref.decode_attention_window_ref(q, k, v, lens, q_rows=t)
    np.testing.assert_allclose(
        np.asarray(skip, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


def test_decode_attention_window_matches_xla_layer_path():
    """The [b, t, nH, hd] wrapper (`layers.decode_attention_pim`) against
    `layers.decode_attention_xla` — the routing-level oracle pair that
    attention_block dispatches between."""
    from repro.models.layers import decode_attention_pim, decode_attention_xla
    b, t, nh, nkv, hd, skv = 3, 4, 6, 2, 32, 128
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, t, nh, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, nkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, nkv, hd), jnp.float32)
    pos = jnp.asarray([0, 17, 124], jnp.int32)   # incl. pos=0 and near-full
    want = decode_attention_xla(q, k, v, cache_len=pos + t, q_offset=pos)
    got = decode_attention_pim(q, k, v, lens=pos + t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fc_gemv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,K,N,bk,bn",
    [
        (1, 512, 256, 128, 128),     # pure GEMV
        (8, 1024, 512, 256, 256),    # RLP*TLP = 8
        (32, 768, 384, 256, 128),    # ragged blocks
        (4, 256, 256, 256, 256),     # single block
    ],
)
def test_fc_gemv_sweep(m, K, N, bk, bn, dtype):
    keys = jax.random.split(jax.random.PRNGKey(2), 2)
    x = jax.random.normal(keys[0], (m, K), dtype)
    w = jax.random.normal(keys[1], (K, N), dtype) / np.sqrt(K)
    got = fc_gemv(x, w, block_k=bk, block_n=bn, interpret=True)
    want = ref.fc_gemv_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_fc_variants_identical():
    """PAPI's two FC paths (pu / pim) must be numerically interchangeable —
    the scheduler flips between them at runtime."""
    from repro.kernels.ops import fc_forward
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (512, 512), jnp.float32) / 32
    a = fc_forward(x, w, "pu")
    b = fc_forward(x, w, "pim", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,nh,l,hp,n,chunk",
    [
        (2, 2, 128, 32, 16, 32),
        (1, 4, 256, 64, 64, 64),
        (2, 1, 64, 64, 128, 64),    # single chunk
        (1, 2, 96, 32, 16, 32),     # 3 chunks
    ],
)
def test_ssd_scan_sweep(b, nh, l, hp, n, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    dtx = (jax.random.normal(keys[0], (b, nh, l, hp)) * 0.5).astype(dtype)
    # realistic decays: lt = dt * A with dt ~ softplus, A in [-16, -1]
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, nh, l)) - 1.0)
    A = -jnp.exp(jax.random.uniform(keys[2], (nh,), minval=0.0, maxval=2.0))
    lt = (dt * A[None, :, None]).astype(jnp.float32)
    B = (jax.random.normal(keys[3], (b, l, n)) * 0.5).astype(dtype)
    C = (jax.random.normal(keys[0], (b, l, n)) * 0.5).astype(dtype)
    got = ssd_scan(dtx, lt, B, C, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(dtx, lt, B, C)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


def test_ssd_scan_matches_model_chunked_path():
    """The Pallas kernel and the model's pure-JAX chunked SSD must agree."""
    from repro.models.ssm import _ssd_chunked
    b, nh, l, hp, n, chunk = 2, 2, 128, 32, 16, 32
    keys = jax.random.split(jax.random.PRNGKey(6), 5)
    x = jax.random.normal(keys[0], (b, l, nh, hp), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, l, nh)) - 1.0)
    A = -jnp.exp(jax.random.uniform(keys[2], (nh,), minval=0.0, maxval=2.0))
    B = jax.random.normal(keys[3], (b, l, n), jnp.float32) * 0.5
    C = jax.random.normal(keys[4], (b, l, n), jnp.float32) * 0.5

    y_model, _ = _ssd_chunked(x, dt, A, B, C, chunk)

    dtx = jnp.moveaxis(dt[..., None] * x, 1, 2)      # [b, nh, l, hp]
    lt = jnp.moveaxis(dt * A[None, None, :], 1, 2)   # [b, nh, l]
    Bm, Cm = B, C
    y_kernel = ssd_scan(dtx, lt, Bm, Cm, chunk=chunk, interpret=True)
    y_kernel = jnp.moveaxis(y_kernel, 1, 2)          # [b, l, nh, hp]
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_model), rtol=1e-4, atol=1e-4
    )
