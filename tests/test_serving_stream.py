"""Continuous-batching streaming front end: serve() vs the offline oracle.

The tentpole contract of the serve loop (`PapiEngine.serve`):

  * token streams under live Poisson-ish arrivals are BIT-IDENTICAL to the
    offline ``submit()`` + ``run()`` batch oracle for the same request set
    — greedy and speculative, dense and paged KV;
  * every committed token is streamed exactly once, in order, with
    contiguous indices, and the final event carries the full `ServeResult`;
  * per-request latencies (queue delay / TTFT / TPOT) are stamped, and the
    iteration-valued ones are deterministic for a fixed arrival schedule;
  * admission stays FIFO under arbitrary arrival/deferral/preemption/
    cancel interleavings, and every submitted request terminates with a
    valid ``finished_reason`` (property-tested via tests/_propcompat.py).
"""
import jax
import numpy as np
import pytest

from _propcompat import given, settings, st
from repro.configs import get_config
from repro.models import init_params
from repro.serving import (FaultInjector, PapiEngine, ServeRequest,
                           latency_summary, percentile)

VALID_REASONS = {"eos", "length", "rejected", "cancelled", "timeout",
                 "aborted"}


_MODEL_CACHE: dict = {}


def _model():
    """Module-lazy model: shared with the fixture AND the property test
    (the _propcompat fallback runner can't mix fixtures with @given)."""
    if "m" not in _MODEL_CACHE:
        cfg = get_config("qwen2-0.5b").reduced()
        _MODEL_CACHE["m"] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _MODEL_CACHE["m"]


@pytest.fixture(scope="module")
def small_model():
    return _model()


@pytest.fixture(scope="module")
def draft_model():
    cfg = get_config("qwen2-0.5b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(9))


def _mk_engine(cfg, params, **kw):
    defaults = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=1, debug_invariants=True)
    defaults.update(kw)
    return PapiEngine(cfg, params, **defaults)


def _requests(seed, n, vocab, max_prompt=30, max_new=10):
    """Mixed workload: prompts straddling the prefill window (some chunk)."""
    rng = np.random.default_rng(seed)
    return [ServeRequest(i,
                         [int(t) for t in rng.integers(3, vocab - 1,
                                                       rng.integers(3, max_prompt))],
                         int(rng.integers(2, max_new)))
            for i in range(n)]


def _schedule(reqs, gaps):
    """Arrival trace: gaps[i] quiet iterations before request i arrives."""
    sched = []
    for req, gap in zip(reqs, gaps):
        sched.extend([[]] * gap)
        sched.append([ServeRequest(req.req_id, list(req.prompt),
                                   req.max_new_tokens,
                                   deadline_s=req.deadline_s)])
    return sched


def _offline(cfg, params, reqs, **kw):
    eng = _mk_engine(cfg, params, **kw)
    for r in reqs:
        eng.submit(ServeRequest(r.req_id, list(r.prompt), r.max_new_tokens))
    return {r.req_id: r.tokens for r in eng.run(max_iterations=500)}


def _serve(cfg, params, reqs, gaps, **kw):
    eng = _mk_engine(cfg, params, **kw)
    streams: dict[int, list[int]] = {}
    finals = {}
    for ev in eng.serve(_schedule(reqs, gaps)):
        if ev.finished:
            assert ev.token == -1 and ev.result is not None
            assert ev.index == len(ev.result.tokens)
            assert ev.reason == ev.result.finished_reason
            finals[ev.req_id] = ev.result
        else:
            streams.setdefault(ev.req_id, []).append(ev.token)
            # contiguous 0-based indices: exactly-once, in-order streaming
            assert ev.index == len(streams[ev.req_id]) - 1
    assert set(finals) == {r.req_id for r in reqs}
    for rid, res in finals.items():
        assert streams.get(rid, []) == res.tokens
    return {rid: res.tokens for rid, res in finals.items()}, finals, eng


GAPS = [0, 0, 2, 0, 1, 3, 0, 5]


@pytest.mark.parametrize("kv", ["dense", "paged"])
def test_serve_greedy_identity_vs_offline(small_model, kv):
    cfg, params = small_model
    kw = dict(kv_layout=kv, page_size=4) if kv == "paged" else {}
    reqs = _requests(7, 8, cfg.vocab_size)
    offline = _offline(cfg, params, reqs, **kw)
    live, finals, _ = _serve(cfg, params, reqs, GAPS, **kw)
    assert live == offline


@pytest.mark.parametrize("kv", ["dense", "paged"])
def test_serve_speculative_identity_vs_offline(small_model, draft_model, kv):
    cfg, params = small_model
    kw = dict(spec_len=3, draft=draft_model)
    if kv == "paged":
        kw.update(kv_layout="paged", page_size=4)
    reqs = _requests(11, 6, cfg.vocab_size)
    offline = _offline(cfg, params, reqs, **kw)
    live, _, _ = _serve(cfg, params, reqs, GAPS, **kw)
    assert live == offline


def test_serve_mixes_prefill_and_decode_waves(small_model):
    """A long prompt arriving mid-decode must NOT stall the running
    decodes: iterations with both prefill_slots and decode_slots > 0
    exist, and under TLP=1 those mixed iterations dispatch ONE device
    program (no extra transfers vs a plain decode iteration)."""
    cfg, params = small_model
    long_prompt = [int(t) for t in
                   np.random.default_rng(3).integers(3, cfg.vocab_size - 1, 40)]
    eng = _mk_engine(cfg, params)
    sched = [[ServeRequest(0, [3, 5, 7], 30)],
             [], [],
             [ServeRequest(1, long_prompt, 4)]]
    for _ in eng.serve(sched):
        pass
    mixed = [s for s in eng.stats if s.prefill_slots and s.decode_slots]
    assert mixed, "no mixed prefill/decode iterations recorded"
    plain = [s for s in eng.stats
             if s.decode_slots and not s.prefill_slots and not s.arrivals]
    assert plain
    # one fused program -> same host-transfer count as a pure-decode step
    assert min(m.transfers for m in mixed) <= max(p.transfers for p in plain)


def test_serve_latency_metrics_deterministic(small_model):
    """Iteration-valued latencies are a pure function of the arrival
    schedule; wall-clock ones are positive and ordered sanely."""
    cfg, params = small_model
    reqs = _requests(5, 6, cfg.vocab_size)

    def run():
        _, finals, _ = _serve(cfg, params, reqs, GAPS)
        return finals

    a, b = run(), run()
    for rid, res in a.items():
        assert res.queue_delay_iters is not None
        assert res.ttft_iters is not None
        assert res.ttft_iters >= res.queue_delay_iters >= 0
        assert res.ttft_s >= res.queue_delay_s >= 0.0
        assert res.tpot_s >= 0.0
        assert b[rid].queue_delay_iters == res.queue_delay_iters
        assert b[rid].ttft_iters == res.ttft_iters
    summary = latency_summary(a.values())
    assert summary["n"] == len(reqs)
    assert summary["ttft_iters"]["p99"] >= summary["ttft_iters"]["p50"]


def test_serve_iterstats_counters(small_model):
    cfg, params = small_model
    reqs = _requests(9, 5, cfg.vocab_size, max_prompt=20)
    _, _, eng = _serve(cfg, params, reqs, [0, 1, 1, 2, 0])
    assert sum(s.arrivals for s in eng.stats) == len(reqs)
    assert any(s.queued > 0 for s in eng.stats) or len(reqs) <= 4
    assert any(s.prefill_slots > 0 for s in eng.stats)
    assert any(s.decode_slots > 0 for s in eng.stats)


def test_serve_idle_gaps_and_trailing_drain(small_model):
    """Quiet ticks between arrivals don't stall the watchdog, and the loop
    drains everything after the arrival stream closes."""
    cfg, params = small_model
    eng = _mk_engine(cfg, params, stall_limit=16)
    sched = [[ServeRequest(0, [3, 5], 3)]] + [[]] * 30 + \
            [[ServeRequest(1, [7, 11], 3)]]
    finals = [ev for ev in eng.serve(sched) if ev.finished]
    assert sorted(ev.req_id for ev in finals) == [0, 1]


def test_serve_offline_engines_unchanged(small_model):
    """run() after serve() on the same engine behaves offline again (the
    stream_chunks flag is scoped to the generator's lifetime)."""
    cfg, params = small_model
    eng = _mk_engine(cfg, params)
    for _ in eng.serve([[ServeRequest(0, [3, 5, 7], 3)]]):
        pass
    assert eng.stream_chunks is False
    eng.submit(ServeRequest(1, [5, 7, 11], 3))
    res = eng.run(max_iterations=100)
    assert {r.req_id for r in res} == {0, 1}


def test_serve_nan_fault_degrades_but_streams_identically(small_model):
    """A NaN fault during mixed waves degrades onto the oracle wave; the
    stream still matches the fault-free serve run (greedy oracle = same
    argmax)."""
    cfg, params = small_model
    reqs = _requests(13, 5, cfg.vocab_size)
    clean, _, _ = _serve(cfg, params, reqs, GAPS)
    faults = FaultInjector(seed=5, nan_p=0.3)
    noisy, _, eng = _serve(cfg, params, reqs, GAPS, faults=faults)
    assert noisy == clean
    assert eng.degraded_steps > 0


def test_percentile_nearest_rank():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    vals = list(range(1, 101))
    assert percentile(vals, 50) == 50
    assert percentile(vals, 99) == 99
    assert percentile(vals, 100) == 100
    assert percentile([3, 1, 2], 0) == 1


# --------------------------------------------------------------------------
# FIFO-fairness property: under random arrival/deferral/preemption/cancel
# interleavings, no request is ever FIRST-admitted before an older
# still-admissible one, and every submitted request terminates.
# --------------------------------------------------------------------------
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_serve_fifo_fairness_property(seed):
    cfg, params = _model()
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 9))
    reqs = _requests(seed, n, cfg.vocab_size, max_prompt=24, max_new=8)
    gaps = [int(g) for g in rng.integers(0, 3, n)]
    # a tight paged pool so deferral + pool-pressure preemption fire, plus
    # injected admission faults for extra deferral interleavings
    eng = _mk_engine(cfg, params, kv_layout="paged", page_size=4,
                     num_pages=24, preempt_after=2,
                     faults=FaultInjector(seed=seed, admit_p=0.2))
    cancel_at = {int(rng.integers(2, 30)): int(rng.integers(0, n))
                 for _ in range(int(rng.integers(0, 3)))}
    finals = {}
    gen = eng.serve(_schedule(reqs, gaps))
    for ev in gen:
        if ev.finished:
            finals[ev.req_id] = ev.result
        rid = cancel_at.pop(eng.iteration, None)
        if rid is not None:
            eng.cancel(rid)
    # termination: one result per submitted request, valid reason
    assert set(finals) == {r.req_id for r in reqs}
    for res in finals.values():
        assert res.finished_reason in VALID_REASONS
    # FIFO first-admission order: submission order is req_id order here
    # (arrivals are scheduled in id order); preempted requests keep their
    # original admit_iteration, so requeues can't reorder this
    admits = [eng.admit_iteration[r.req_id] for r in reqs
              if r.req_id in eng.admit_iteration]
    assert admits == sorted(admits), (
        f"younger request first-admitted before an older admissible one: "
        f"{admits} (seed {seed})")
