"""Chunked prefill tests: long prompts through the fixed prefill window.

The contract under test (ISSUE 4 acceptance):
  * a prompt of length >= 4 x prefill_len completes UNTRUNCATED on both KV
    layouts, and its greedy stream equals a one-shot prefill + decode loop
    on the raw model (the oracle the chunk waves must be invisible to);
  * a prompt <= prefill_len takes exactly the pre-chunking path — streams
    are bit-identical to an engine whose window holds every prompt one-shot;
  * the ragged final chunk (prompt length not a multiple of the window)
    masks its tail writes instead of corrupting neighbouring cache rows;
  * chunked admission composes with speculative decoding (the draft cache
    chunks the same prompt positions) and with requests already decoding in
    other slots when the chunk waves run;
  * a prompt the dense slab cannot hold AT ALL is rejected honestly
    (finished_reason="rejected") — never silently truncated — while the
    paged engine completes it from the pooled pages.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, init_cache, init_params, mixed_step,
                          prefill)
from repro.serving import PapiEngine, ServeRequest


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def draft_model():
    cfg = get_config("qwen2-0.5b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(9))


# an eos random-init weights essentially never argmax to: generation lengths
# stay deterministic, so budgets (not eos luck) end every request
NO_EOS = get_config("qwen2-0.5b").reduced().vocab_size - 1


def _oracle(cfg, params, prompt, n_new, capacity=160):
    """One-shot prefill of the WHOLE prompt + greedy decode loop on the raw
    model — what chunked admission must be indistinguishable from."""
    cache = init_cache(cfg, 1, capacity)
    logits, cache = prefill(
        cfg, params,
        {"tokens": jnp.asarray([prompt], jnp.int32),
         "prompt_lens": jnp.asarray([len(prompt)], jnp.int32)},
        cache,
    )
    toks = [int(np.argmax(np.asarray(logits[0])))]
    for _ in range(n_new - 1):
        lg, cache = decode_step(cfg, params, cache, jnp.asarray([[toks[-1]]]))
        toks.append(int(np.argmax(np.asarray(lg[0, 0]))))
    return toks


def _engine(cfg, params, **kw):
    defaults = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=NO_EOS, debug_invariants=True)
    defaults.update(kw)
    return PapiEngine(cfg, params, **defaults)


@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
@pytest.mark.parametrize("plen", [32, 33])   # 4 x window, and a ragged tail
def test_long_prompt_matches_oneshot_oracle(small_model, kv_layout, plen):
    """>= 4 x prefill_len tokens chunk through the 8-token window; the
    greedy stream must equal the raw-model one-shot prefill oracle."""
    cfg, params = small_model
    prompt = list(range(3, 3 + plen))
    want = _oracle(cfg, params, prompt, 6)
    kw = {"page_size": 8} if kv_layout == "paged" else {}
    eng = _engine(cfg, params, kv_layout=kv_layout, **kw)
    eng.submit(ServeRequest(0, prompt, max_new_tokens=6))
    res = eng.run(max_iterations=100)
    assert res[0].tokens == want
    assert res[0].finished_reason == "length"


def test_mixed_lengths_bit_identical_to_wide_window(small_model):
    """Short and long prompts together: the 8-token-window engine (long
    prompts chunk) must emit streams bit-identical to a 64-token-window
    engine (everything one-shot) — i.e. to the pre-chunking engine on every
    prompt that engine could already hold."""
    cfg, params = small_model
    reqs = [(list(range(3 + i, 3 + i + p)), 4 + i)
            for i, p in enumerate([2, 5, 8, 20, 32])]

    def run(prefill_len):
        eng = _engine(cfg, params, prefill_len=prefill_len)
        for i, (prompt, n) in enumerate(reqs):
            eng.submit(ServeRequest(i, prompt, max_new_tokens=n))
        return {r.req_id: (r.tokens, r.finished_reason)
                for r in eng.run(max_iterations=200)}

    assert run(8) == run(64)


@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_chunked_admission_interleaved_with_running_decodes(small_model,
                                                            kv_layout):
    """A long prompt's chunk waves run while another slot is mid-decode:
    the masked chunk writes must leave the live slot's KV untouched (its
    stream equals its solo run) and the chunked request still matches the
    oracle."""
    cfg, params = small_model
    kw = {"page_size": 8} if kv_layout == "paged" else {}
    short, long_p = [3, 5, 7], list(range(3, 3 + 32))
    want_short = _oracle(cfg, params, short, 20)
    want_long = _oracle(cfg, params, long_p, 6)

    eng = _engine(cfg, params, kv_layout=kv_layout, **kw)
    eng.submit(ServeRequest(0, short, max_new_tokens=20))
    eng.step()
    eng.step()                       # slot 0 is decoding...
    eng.submit(ServeRequest(1, long_p, max_new_tokens=6))   # ...now chunk in
    res = {r.req_id: r.tokens for r in eng.run(max_iterations=200)}
    assert res[0] == want_short
    assert res[1] == want_long


@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_speculative_chunked_prefill_lossless(small_model, draft_model,
                                              kv_layout):
    """Chunked admission fills BOTH caches (target and draft) at the same
    prompt positions; greedy speculation stays lossless, so the stream must
    still equal the plain one-shot oracle."""
    cfg, params = small_model
    prompt = list(range(3, 3 + 32))
    want = _oracle(cfg, params, prompt, 8)
    kw = {"page_size": 8} if kv_layout == "paged" else {}
    eng = _engine(cfg, params, kv_layout=kv_layout, spec_len=3,
                  draft=draft_model, **kw)
    eng.submit(ServeRequest(0, prompt, max_new_tokens=8))
    assert eng.run(max_iterations=100)[0].tokens == want


def test_dense_rejects_prompt_beyond_slab_capacity(small_model):
    """Honest rejection replaced truncation: a prompt the dense slab cannot
    hold (prompt + 1 token + spec window > cache_capacity) is rejected with
    empty tokens — and NO truncation warning fires for any long prompt."""
    cfg, params = small_model
    eng = _engine(cfg, params, cache_capacity=16)
    eng.submit(ServeRequest(0, list(range(3, 3 + 20)), max_new_tokens=5))
    eng.submit(ServeRequest(1, list(range(3, 3 + 14)), max_new_tokens=5))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = {r.req_id: r for r in eng.run(max_iterations=50)}
    assert not any("prefill_len" in str(w.message) for w in caught)
    assert res[0].finished_reason == "rejected" and res[0].tokens == []
    # 14 + 1 + 1 = 16 fits exactly: chunked in (2 windows), 1-token budget
    assert res[1].finished_reason == "length" and len(res[1].tokens) == 1


def test_paged_long_prompt_beyond_dense_capacity(small_model):
    """THE long-context scenario chunking unlocks: an 80-token prompt
    exceeds the 64-token dense slab (dense rejects honestly) but chunks
    into the paged pool and completes, matching the oracle."""
    cfg, params = small_model
    prompt = list(range(3, 3 + 80))
    want = _oracle(cfg, params, prompt, 10)

    dense = _engine(cfg, params)
    dense.submit(ServeRequest(0, prompt, max_new_tokens=10))
    assert dense.run(max_iterations=50)[0].finished_reason == "rejected"

    paged = _engine(cfg, params, kv_layout="paged", page_size=16)
    paged.submit(ServeRequest(0, prompt, max_new_tokens=10))
    res = paged.run(max_iterations=100)
    assert res[0].tokens == want and res[0].finished_reason == "length"
    assert paged.kv.alloc.mapped_count == 0      # pool drained afterwards


def test_mixed_step_chunk_of_one_is_decode_step(small_model):
    """A decode is a chunk of length 1: `mixed_step` on a row with
    chunk_lens == 1 holding the slot's last token is BITWISE `decode_step`
    on that slot — same logits, same cache writes, same pos advance.  This
    is the contract that lets the serve loop pack ongoing decodes and
    prefill waves into one device program."""
    cfg, params = small_model
    cache = init_cache(cfg, 2, 32)
    prompts = jnp.asarray([[3, 5, 7, 11], [4, 6, 8, 10]], jnp.int32)
    logits, cache = prefill(
        cfg, params,
        {"tokens": prompts, "prompt_lens": jnp.asarray([4, 4], jnp.int32)},
        cache)
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    want_logits, want_cache = decode_step(cfg, params, cache, toks[:, None])

    window = jnp.zeros((2, 8), jnp.int32).at[:, 0].set(toks)
    got_logits, got_cache = mixed_step(
        cfg, params, cache, window,
        chunk_lens=jnp.ones(2, jnp.int32),
        pin_mask=jnp.zeros(2, bool),
        pin_pos=jnp.zeros(2, jnp.int32))

    np.testing.assert_array_equal(np.asarray(got_logits),
                                  np.asarray(want_logits[:, 0]))
    for a, b in zip(jax.tree_util.tree_leaves(want_cache),
                    jax.tree_util.tree_leaves(got_cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_streams_chunked_admission_bit_identical(small_model):
    """A 33-token prompt arriving LIVE, mid-decode of another request,
    streams exactly the one-shot oracle's tokens — its chunk waves ride
    the mixed serve program without perturbing the running decode."""
    cfg, params = small_model
    long_prompt = [int(t) for t in
                   np.random.default_rng(0).integers(3, cfg.vocab_size - 1,
                                                     33)]
    short = [3, 5, 7]
    eng = _engine(cfg, params)
    sched = [[ServeRequest(0, short, max_new_tokens=12)], [],
             [ServeRequest(1, long_prompt, max_new_tokens=6)]]
    streams: dict[int, list[int]] = {}
    for ev in eng.serve(sched):
        if not ev.finished:
            streams.setdefault(ev.req_id, []).append(ev.token)
    assert streams[0] == _oracle(cfg, params, short, 12)
    assert streams[1] == _oracle(cfg, params, long_prompt, 6)
    assert any(s.prefill_slots and s.decode_slots for s in eng.stats)
