"""Training stack tests: optimizer, accumulation, compression, checkpoint
restart (incl. elastic resharding semantics), data determinism, watchdog."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcompat import given, settings, st

from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.models import forward_train, init_params
from repro.training import (
    AdamWConfig,
    CheckpointManager,
    StepWatchdog,
    TrainConfig,
    adamw_update,
    compress,
    compress_with_feedback,
    decompress,
    init_adamw,
    init_error,
    lr_schedule,
    make_train_step,
    run_training,
    zero1_logical_axes,
)

CFG = get_config("qwen2-0.5b").reduced()


def test_loss_decreases_over_training(tmp_path):
    tcfg = TrainConfig(steps=30, checkpoint_every=100, log_every=100,
                       checkpoint_dir=str(tmp_path), remat=False)
    dcfg = DataConfig(batch=4, seq_len=32)
    res = run_training(CFG, tcfg, dcfg, AdamWConfig(lr=1e-3, warmup_steps=5,
                                                    total_steps=30))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.2, (first, last)


def test_grad_accumulation_matches_large_batch():
    """accum=2 over half-batches == one step on the full batch."""
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10, grad_clip=1e9)
    params = init_params(CFG, jax.random.PRNGKey(0))
    batch = jax.tree.map(
        jnp.asarray, make_batch(CFG, DataConfig(batch=8, seq_len=16), 0))

    step1 = make_train_step(CFG, ocfg, accum=1, remat=False)
    p1, _, _, m1 = step1(params, init_adamw(params), {}, batch)

    split = jax.tree.map(
        lambda x: x.reshape((2, x.shape[0] // 2) + x.shape[1:]), batch)
    step2 = make_train_step(CFG, ocfg, accum=2, remat=False)
    p2, _, _, m2 = step2(params, init_adamw(params), {}, split)

    # mean-of-half-grads == full grad (loss is a token mean; equal shards)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    l1, l2 = jax.tree.leaves(p1), jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-4)


def test_remat_matches_no_remat():
    params = init_params(CFG, jax.random.PRNGKey(0))
    batch = jax.tree.map(
        jnp.asarray, make_batch(CFG, DataConfig(batch=2, seq_len=16), 0))
    g1 = jax.grad(lambda p: forward_train(CFG, p, batch, remat=False)[0])(params)
    g2 = jax.grad(lambda p: forward_train(CFG, p, batch, remat=True)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)


class TestCompression:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_error_bounded(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 0.1
        q, s = compress(g)
        deq = decompress(q, s)
        assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-9

    def test_error_feedback_telescopes(self):
        """Sum of (dequantized grads) -> sum of true grads: the residual is
        carried, so the cumulative transported signal is unbiased."""
        key = jax.random.PRNGKey(0)
        true_sum = jnp.zeros((32,))
        sent_sum = jnp.zeros((32,))
        err = {"g": jnp.zeros((32,))}
        for i in range(50):
            key, k = jax.random.split(key)
            g = jax.random.normal(k, (32,)) * 0.01
            true_sum = true_sum + g
            sent, err = compress_with_feedback({"g": g}, err)
            sent_sum = sent_sum + sent["g"]
        resid = float(jnp.max(jnp.abs(true_sum - sent_sum)))
        # residual is bounded by one step's quantization error, not O(T)
        assert resid < 5e-4


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path))
        params = init_params(CFG, jax.random.PRNGKey(0))
        opt = init_adamw(params)
        ckpt.save(7, {"params": params, "opt": opt}, blocking=True)
        assert ckpt.latest_step() == 7
        restored = ckpt.restore(7, {"params": params, "opt": opt})
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resave_same_step_is_idempotent(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path))
        params = {"w": jnp.arange(4.0)}
        ckpt.save(5, {"params": params}, blocking=True)
        params2 = {"w": jnp.arange(4.0) * 2}
        ckpt.save(5, {"params": params2}, blocking=True)   # overwrite
        restored = ckpt.restore(5, {"params": params})
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.arange(4.0) * 2)

    def test_gc_keeps_last_k(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), keep=2)
        params = {"w": jnp.zeros((4,))}
        for s in (1, 2, 3, 4):
            ckpt.save(s, {"params": params}, blocking=True)
        assert ckpt.all_steps() == [3, 4]

    def test_resume_continues_training(self, tmp_path):
        tcfg = TrainConfig(steps=10, checkpoint_every=5, log_every=100,
                           checkpoint_dir=str(tmp_path), remat=False)
        dcfg = DataConfig(batch=2, seq_len=16)
        ocfg = AdamWConfig(lr=1e-3, total_steps=10)
        run_training(CFG, tcfg, dcfg, ocfg)           # writes step 5, 10
        # restart "after crash at step 10" -> resumes from 10, same stream
        tcfg2 = TrainConfig(steps=12, checkpoint_every=50, log_every=100,
                            checkpoint_dir=str(tmp_path), remat=False)
        res = run_training(CFG, tcfg2, dcfg, ocfg, resume=True)
        assert res.resumed_from == 10
        assert len(res.losses) == 2                    # only steps 10, 11


def test_zero1_axes_shard_replicated_states():
    from repro.models import param_logical_axes, param_shapes
    axes = param_logical_axes(CFG)
    st_axes = zero1_logical_axes(axes, param_shapes(CFG))
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    flat_s = jax.tree.leaves(st_axes, is_leaf=lambda x: isinstance(x, tuple))
    # every state leaf either inherits fsdp or gains it on a shardable dim
    assert any("fsdp" in s for s in flat_s)
    for a, s in zip(flat_a, flat_s):
        if "fsdp" in a:
            assert s == a


def test_lr_schedule_shape():
    ocfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_ratio=0.1)
    assert float(lr_schedule(ocfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(ocfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(ocfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_data_pipeline_deterministic_and_sharded():
    d0 = DataConfig(seed=1, batch=8, seq_len=16, num_shards=2, shard=0)
    d1 = DataConfig(seed=1, batch=8, seq_len=16, num_shards=2, shard=1)
    a = make_batch(CFG, d0, step=3)
    b = make_batch(CFG, d0, step=3)
    c = make_batch(CFG, d1, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])   # deterministic
    assert not np.array_equal(a["tokens"], c["tokens"])       # shard-disjoint
    assert a["tokens"].shape == (4, 16)                       # per-shard batch


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=20, threshold=2.0)
    for i in range(15):
        wd.observe(i, 0.1)
    wd.observe(15, 0.5)    # 5x median -> straggler
    wd.observe(16, 0.1)
    assert len(wd.events) == 1
    assert wd.events[0].step == 15
