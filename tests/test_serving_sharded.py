"""Mesh-sharded serving tests (§5.3 layout).

These need >= 8 host devices, so CI runs this file in a dedicated step with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_serving_sharded.py

Under the plain tier-1 invocation (1 CPU device) everything here skips.

What is asserted:
  * the mesh-sharded engine's token streams are identical to the 1-device
    engine's — greedy argmax is invariant to GSPMD's ulp-level reduction
    reordering, so serving output is exactly reproducible across mesh
    shapes;
  * the scheduler's FC_PU <-> FC_PIM flip still takes effect under a mesh
    (each variant traces its own partitioned executable, incl. the
    shard_map'd fc_gemv banks);
  * the head-sharded flash-decode kernel (one Attn-PIM unit per KV shard)
    is bit-identical to the unsharded kernel, standalone and inside the
    engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mesh(dp, tp):
    from repro.launch.mesh import make_serving_mesh
    return make_serving_mesh(dp, tp)


def _run(cfg, params, reqs, **kw):
    defaults = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=1, debug_invariants=True)
    defaults.update(kw)
    eng = PapiEngine(cfg, params, **defaults)
    for i, (prompt, n) in enumerate(reqs):
        eng.submit(ServeRequest(i, prompt, max_new_tokens=n))
    results = eng.run(max_iterations=300)
    streams = {r.req_id: (r.tokens, r.finished_reason) for r in results}
    return streams, eng


REQS = [([3 + i, 5, 7, 11], 4 + 3 * i) for i in range(6)]


@needs8
def test_mesh_tokens_identical_to_one_device(small_model):
    """launch acceptance: 8-way tensor-parallel decode emits the exact token
    stream of the single-device engine, request for request."""
    cfg, params = small_model
    want, _ = _run(cfg, params, REQS)
    got, eng = _run(cfg, params, REQS, mesh=_mesh(1, 8))
    assert eng.mesh is not None
    assert got == want


@needs8
def test_mesh_scheduler_flip_takes_effect(small_model):
    """Under a mesh the FC flip must still switch executables: with staggered
    request lengths both variants appear in the iteration stats, and the
    pim iterations (shard_map'd fc_gemv banks) leave the tokens unchanged."""
    cfg, params = small_model
    want, weng = _run(cfg, params, REQS, alpha=3.0)
    got, eng = _run(cfg, params, REQS, alpha=3.0, mesh=_mesh(1, 8))
    variants = {s.fc_variant for s in eng.stats if s.rlp > 0}
    assert variants == {"pu", "pim"}
    assert eng.scheduler.num_reschedules >= 1
    assert got == want


@needs8
def test_mesh_speculative_matches_one_device(small_model):
    """The fused draft/verify/accept scan partitioned over the mesh accepts
    exactly the same windows as the 1-device engine."""
    cfg, params = small_model
    draft_cfg = get_config("qwen2-0.5b").reduced()
    draft_params = init_params(draft_cfg, jax.random.PRNGKey(9))
    reqs = REQS[:3]
    want, _ = _run(cfg, params, reqs, spec_len=3,
                   draft=(draft_cfg, draft_params))
    got, _ = _run(cfg, params, reqs, spec_len=3,
                  draft=(draft_cfg, draft_params), mesh=_mesh(1, 8))
    assert got == want


@needs8
def test_mesh_dp_axis_also_matches(small_model):
    """A (2, 4) mesh — data-replicated engine x 4 FC banks — same tokens."""
    cfg, params = small_model
    want, _ = _run(cfg, params, REQS[:4])
    got, _ = _run(cfg, params, REQS[:4], mesh=_mesh(2, 4))
    assert got == want


@needs8
def test_decode_attention_sharded_bit_identical():
    """One Attn-PIM unit per KV shard: no cross-shard term exists, so the
    shard_map'd kernel must be BIT-identical to the unsharded one."""
    from repro.kernels import decode_attention, decode_attention_sharded
    b, nkv, g, hd, skv = 2, 8, 2, 32, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, nkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, nkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, nkv, hd), jnp.float32)
    lens = jnp.asarray([37, 128], jnp.int32)
    mesh = _mesh(1, 8)
    got = decode_attention_sharded(q, k, v, lens, mesh=mesh, block_k=32,
                                   interpret=True)
    want = decode_attention(q, k, v, lens, block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs8
def test_decode_attention_sharded_indivisible_heads_fall_back():
    """2 KV heads on an 8-way axis cannot split: the wrapper must fall back
    to the replicated kernel instead of mis-sharding."""
    from repro.kernels import decode_attention, decode_attention_sharded
    b, nkv, g, hd, skv = 2, 2, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, nkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, nkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, nkv, hd), jnp.float32)
    lens = jnp.asarray([11, 64], jnp.int32)
    got = decode_attention_sharded(q, k, v, lens, mesh=_mesh(1, 8),
                                   block_k=32, interpret=True)
    want = decode_attention(q, k, v, lens, block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs8
def test_decode_attention_sharded_windowed_bit_identical():
    """Query windows under the KV-head shard_map (TLP>1 verify form): each
    shard masks its own heads' window rows locally, no cross-shard term —
    bit-identical to the unsharded windowed kernel."""
    from repro.kernels import decode_attention, decode_attention_sharded
    b, nkv, g, hd, skv, t = 2, 8, 2, 32, 128, 3
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, nkv, t * g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, nkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, nkv, hd), jnp.float32)
    lens = jnp.asarray([37, 128], jnp.int32)
    got = decode_attention_sharded(q, k, v, lens, mesh=_mesh(1, 8),
                                   block_k=32, interpret=True, q_rows=t)
    want = decode_attention(q, k, v, lens, block_k=32, interpret=True,
                            q_rows=t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs8
def test_paged_decode_attention_sharded_windowed_bit_identical():
    """The windowed paged kernel under the KV-head shard_map: tables/lens
    replicate, each shard streams its heads' pages for all t window rows —
    bit-identical to the unsharded windowed paged kernel."""
    from repro.kernels import (paged_decode_attention,
                               paged_decode_attention_sharded)
    b, nkv, g, hd, page, nblk, t = 2, 8, 2, 32, 16, 4, 3
    num_pages = b * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (b, nkv, t * g, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, page, nkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, page, nkv, hd), jnp.float32)
    lens = jnp.asarray([37, 64], jnp.int32)
    tables = jnp.asarray(
        np.arange(1, num_pages).reshape(b, nblk), jnp.int32)
    got = paged_decode_attention_sharded(q, kp, vp, lens, tables,
                                         mesh=_mesh(1, 8), interpret=True,
                                         q_rows=t)
    want = paged_decode_attention(q, kp, vp, lens, tables, interpret=True,
                                  q_rows=t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs8
def test_spec_attn_pim_paged_mesh_matches_unsharded_dense(small_model):
    """The full ISSUE 5 composition: speculative verify windows + paged KV
    + the windowed block-table kernel + a (1, 2) KV-head mesh — token
    streams must equal the 1-device dense XLA engine's."""
    cfg, params = small_model
    draft_cfg = get_config("qwen2-0.5b").reduced()
    draft_params = init_params(draft_cfg, jax.random.PRNGKey(9))
    reqs = REQS[:3]
    want, _ = _run(cfg, params, reqs, spec_len=3,
                   draft=(draft_cfg, draft_params))
    got, eng = _run(cfg, params, reqs, spec_len=3,
                    draft=(draft_cfg, draft_params), kv_layout="paged",
                    page_size=16, attn_pim=True, mesh=_mesh(1, 2))
    assert eng.mesh is not None and eng.kv is not None
    assert got == want


@needs8
def test_attn_pim_engine_sharded_matches_unsharded(small_model):
    """The engine's Attn-PIM path (flash-decode kernel) under a (1, 2) mesh —
    exactly one KV head per shard for this GQA config — emits the same
    tokens as the unsharded Attn-PIM engine."""
    cfg, params = small_model
    assert cfg.num_kv_heads == 2
    want, _ = _run(cfg, params, REQS[:3], attn_pim=True)
    got, _ = _run(cfg, params, REQS[:3], attn_pim=True, mesh=_mesh(1, 2))
    assert got == want


@needs8
def test_paged_engine_sharded_matches_unsharded(small_model):
    """Paged KV + KV-head sharding: the paged engine under a (1, 2) mesh
    with the block-table Pallas kernel (one Attn-PIM unit per KV-head
    shard, pages resolved in the index_map) emits the same tokens as the
    unsharded paged engine AND the unsharded dense engine."""
    cfg, params = small_model
    want, _ = _run(cfg, params, REQS[:3])
    paged, _ = _run(cfg, params, REQS[:3], kv_layout="paged", page_size=16,
                    attn_pim=True)
    sharded, eng = _run(cfg, params, REQS[:3], kv_layout="paged",
                        page_size=16, attn_pim=True, mesh=_mesh(1, 2))
    assert eng.mesh is not None and eng.kv is not None
    assert paged == want
    assert sharded == want


@needs8
def test_paged_engine_sharded_xla_path_matches_unsharded(small_model):
    """Paged + mesh WITHOUT attn_pim: the pool dim cannot shard (physical
    page ids index the whole pool), so the engine must still store the
    pools head-sharded — the default rules under a mesh switch to the
    attn_pim table for any paged engine — and the XLA page-gather decode
    path must emit the same tokens as the unsharded engines."""
    from repro.distributed.sharding import serve_rules
    cfg, params = small_model
    want, _ = _run(cfg, params, REQS[:3])
    got, eng = _run(cfg, params, REQS[:3], kv_layout="paged", page_size=16,
                    mesh=_mesh(1, 2))
    assert got == want
    assert eng.rules == serve_rules(attn_pim=True)


@needs8
def test_paged_mesh_chunked_prefill_matches_unsharded(small_model):
    """Chunked admission under a mesh: a prompt 4x the prefill window runs
    its chunk waves through the partitioned chunk step (paged scatter over
    head-sharded pools) and must emit the exact tokens of the 1-device
    DENSE engine, alongside a short prompt admitted in the same wave."""
    cfg, params = small_model
    no_eos = cfg.vocab_size - 1
    reqs = [(list(range(3, 3 + 33)), 6), ([3, 5, 7], 5)]
    want, _ = _run(cfg, params, reqs, eos_token=no_eos)
    got, eng = _run(cfg, params, reqs, eos_token=no_eos,
                    kv_layout="paged", page_size=8, mesh=_mesh(1, 2))
    assert eng.mesh is not None
    assert got == want


@needs8
def test_paged_decode_attention_sharded_bit_identical():
    """The paged kernel shard_mapped over KV heads (tables/lens replicated,
    page pools split on the head dim) must be BIT-identical to the
    unsharded paged kernel."""
    from repro.kernels import (paged_decode_attention,
                               paged_decode_attention_sharded)
    b, nkv, g, hd, page, nblk = 2, 8, 2, 32, 16, 4
    num_pages = b * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, nkv, g, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, page, nkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, page, nkv, hd), jnp.float32)
    lens = jnp.asarray([37, 64], jnp.int32)
    tables = jnp.asarray(
        np.arange(1, num_pages).reshape(b, nblk), jnp.int32)
    mesh = _mesh(1, 8)
    got = paged_decode_attention_sharded(q, kp, vp, lens, tables, mesh=mesh,
                                         interpret=True)
    want = paged_decode_attention(q, kp, vp, lens, tables, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs8
def test_sharded_fc_gemv_col_banks_bit_identical():
    """Column-split FC-PIM banks concatenate without any cross-bank
    reduction — bit-identical to the single-bank kernel."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels import fc_gemv
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 256), jnp.float32)
    mesh = _mesh(1, 8)
    got = shard_map(lambda xs, ws: fc_gemv(xs, ws, interpret=True),
                    mesh=mesh, in_specs=(P(), P(None, "model")),
                    out_specs=P(None, "model"), check_rep=False)(x, w)
    want = fc_gemv(x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs8
def test_mesh_serve_streaming_matches_unsharded(small_model):
    """Continuous-batching serve() composes with the mesh: live staggered
    arrivals under 8-way tensor parallelism stream the exact token
    sequences of the unsharded offline batch run."""
    cfg, params = small_model
    want, _ = _run(cfg, params, REQS)

    eng = PapiEngine(cfg, params, max_slots=4, cache_capacity=64,
                     prefill_len=8, alpha=6.0, eos_token=1,
                     debug_invariants=True, mesh=_mesh(1, 8))
    sched = []
    for i, (prompt, n) in enumerate(REQS):
        sched.append([ServeRequest(i, prompt, max_new_tokens=n)])
        sched.append([])
    streams: dict[int, list[int]] = {}
    finals = {}
    for ev in eng.serve(sched):
        if ev.finished:
            finals[ev.req_id] = (ev.result.tokens, ev.result.finished_reason)
        else:
            streams.setdefault(ev.req_id, []).append(ev.token)
    assert finals == want
    for rid, (toks, _) in finals.items():
        assert streams.get(rid, []) == toks
