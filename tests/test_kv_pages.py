"""Property tests for the paged KV allocator (serving/kv_pages.py).

Run with hypothesis when installed, with the deterministic fallback sampler
otherwise (see tests/_propcompat.py).  The core claims:

  * arbitrary admit/grow/rewind/release interleavings never map one
    physical page to two owners, never leak pages, and never let the
    reservation total exceed the free pool (so grow() can't fail);
  * block-table rows always mirror the allocator exactly: mapped pages as
    the prefix, the garbage page everywhere else, page 0 never mapped;
  * a drained pool is indistinguishable from a fresh one.
"""
import numpy as np
import pytest

from _propcompat import given, settings, st
from repro.serving.kv_pages import (GARBAGE_PAGE, BlockTables, PageAllocator,
                                    PagedKVManager, pages_for)

MAX_SLOTS = 4


def _decode_op(x: int) -> tuple[int, int, int]:
    """Map one drawn integer onto (op, slot, tokens) — keeps the strategy
    surface to plain integer lists, which both hypothesis and the fallback
    sampler provide."""
    return x % 4, (x // 4) % MAX_SLOTS, (x // 16) % 120 + 1


def _apply(mgr: PagedKVManager, live: dict, x: int) -> None:
    op, slot, tokens = _decode_op(x)
    if op == 0 and slot not in live:                      # admit
        if mgr.can_admit(tokens):
            mgr.admit(slot, tokens, max(1, tokens // 2))
            live[slot] = tokens
    elif op == 1 and slot in live:                        # grow coverage
        mgr.ensure(slot, min(tokens, live[slot]))
    elif op == 2 and slot in live:                        # speculative rewind
        mgr.rewind(slot, tokens)
    elif op == 3 and slot in live:                        # finish
        mgr.release(slot)
        live.pop(slot)


def _check_tables(mgr: PagedKVManager, live: dict) -> None:
    for s in range(MAX_SLOTS):
        pages = mgr.alloc.pages_of(s)
        row = mgr.tables.host[s]
        assert list(row[:len(pages)]) == pages
        assert all(int(e) == GARBAGE_PAGE for e in row[len(pages):])
        if s not in live:
            assert not pages
    mapped = [p for s in live for p in mgr.alloc.pages_of(s)]
    assert GARBAGE_PAGE not in mapped, "garbage page must never be mapped"


@settings(max_examples=40)
@given(st.lists(st.integers(0, 2**20), min_size=0, max_size=60))
def test_allocator_invariants_under_random_ops(ops):
    mgr = PagedKVManager(num_pages=25, page_size=8, max_slots=MAX_SLOTS)
    live: dict[int, int] = {}
    for x in ops:
        _apply(mgr, live, x)
        mgr.alloc.check()
        _check_tables(mgr, live)
    for s in list(live):
        mgr.release(s)
    mgr.alloc.check()
    assert mgr.alloc.mapped_count == 0
    assert mgr.alloc.reserved_unmapped == 0
    assert mgr.alloc.free_count == mgr.alloc.num_pages
    assert (mgr.tables.host == GARBAGE_PAGE).all()


@settings(max_examples=20)
@given(st.lists(st.integers(0, 2**20), min_size=0, max_size=40),
       st.integers(1, 16), st.integers(6, 40))
def test_allocator_invariants_across_geometries(ops, page_size, num_pages):
    mgr = PagedKVManager(num_pages=num_pages, page_size=page_size,
                         max_slots=MAX_SLOTS)
    live: dict[int, int] = {}
    for x in ops:
        _apply(mgr, live, x)
        mgr.alloc.check()
    for s in list(live):
        mgr.release(s)
    mgr.alloc.check()
    assert mgr.alloc.free_count == mgr.alloc.num_pages


def test_pages_for():
    assert pages_for(0, 8) == 1     # an owner always holds >= 1 page
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(17, 8) == 3


def test_admission_headroom_accounts_for_reservations():
    """Reserved-but-unmapped pages must be invisible to later admissions —
    otherwise a running request's grow() could fail mid-flight."""
    a = PageAllocator(10, 4)
    a.admit(0, budget_pages=8, initial_pages=2)   # 6 reserved unmapped
    assert a.free_count == 8
    assert a.available == 2
    assert a.can_admit(2) and not a.can_admit(3)
    # the reservation is really claimable: grow to the full budget
    a.grow(0, 6)
    assert a.free_count == 2 and a.reserved_unmapped == 0
    a.check()


def test_rewind_keeps_reservation_claimable():
    a = PageAllocator(8, 4)
    a.admit(0, budget_pages=6, initial_pages=6)
    freed = a.rewind(0, keep_pages=2)
    assert len(freed) == 4
    assert a.free_count == 6
    # the 4 freed pages stay promised to owner 0:
    assert a.available == 2
    assert not a.can_admit(3)
    again = a.grow(0, 4)                  # guaranteed to succeed
    assert set(again) <= set(freed) | set(range(8))
    a.check()


def test_grow_beyond_reservation_draws_uncommitted_headroom():
    """A widened speculative window may need more than was reserved; the
    overage comes from uncommitted pages only and can fail cleanly."""
    a = PageAllocator(10, 4)
    a.admit(0, budget_pages=3, initial_pages=3)
    a.admit(1, budget_pages=5, initial_pages=1)   # 4 reserved
    # free = 6, reserved = 4 -> owner 0 may overdraw at most 2
    a.grow(0, 2)
    with pytest.raises(MemoryError):
        a.grow(0, 1)
    a.check()


def test_reserve_more_widens_and_shrinks_reservations():
    """Mid-flight re-budgeting (the engine's set_spec_len under the paged
    layout): widening draws on uncommitted headroom only and fails cleanly;
    shrinking clamps at zero even when mapped pages already exceed the new
    budget."""
    a = PageAllocator(10, 4)
    a.admit(0, budget_pages=4, initial_pages=2)   # 2 reserved
    a.admit(1, budget_pages=4, initial_pages=4)   # 0 reserved
    assert a.available == 2
    a.reserve_more(0, 2)                          # widen into headroom
    assert a.available == 0 and a.reserved_unmapped == 4
    with pytest.raises(MemoryError):
        a.reserve_more(1, 1)                      # nothing uncommitted left
    a.grow(0, 4)                                  # full widened budget lands
    a.reserve_more(0, -3)                         # shrink clamps at zero
    assert a.reserved_unmapped == 0
    a.check()


def test_finish_releases_everything():
    a = PageAllocator(6, 4)
    a.admit(7, budget_pages=5, initial_pages=3)
    a.finish(7)
    assert a.free_count == 6 and a.reserved_unmapped == 0
    assert a.owners() == []
    a.check()


def test_admit_over_capacity_raises():
    a = PageAllocator(4, 4)
    with pytest.raises(MemoryError):
        a.admit(0, budget_pages=5, initial_pages=1)


def test_fragmentation_and_watermark():
    a = PageAllocator(10, page_size=8)
    a.admit(0, budget_pages=4, initial_pages=3)   # 24 rows mapped
    assert a.stats(used_tokens=18).fragmentation == pytest.approx(0.25)
    assert a.stats(used_tokens=24).fragmentation == 0.0
    assert a.watermark == 3
    a.rewind(0, keep_pages=1)
    assert a.watermark == 3                       # watermark is a peak
    a.grow(0, 3)
    assert a.watermark == 4


def test_block_tables_device_cache_invalidates_on_mutation():
    t = BlockTables(2, 4)
    d0 = t.device()
    assert d0 is t.device()                       # cached while clean
    t.set_row(1, [5, 6])
    d1 = t.device()
    assert d1 is not d0
    assert np.asarray(d1)[1].tolist() == [5, 6, GARBAGE_PAGE, GARBAGE_PAGE]
    t.clear_row(1)
    assert np.asarray(t.device())[1].tolist() == [GARBAGE_PAGE] * 4


def test_manager_clamps_table_width_to_pool():
    """max_blocks wider than the usable pool would admit budgets the
    allocator can never satisfy even when fully drained — the request
    would defer forever (engine livelock).  The manager clamps."""
    m = PagedKVManager(num_pages=9, page_size=8, max_slots=2, max_blocks=100)
    assert m.max_blocks == 8
    assert m.max_context == 64
    # ... and the actual table is the clamped width too (a wider device
    # table would re-inflate the gathered KV view the cap exists to bound)
    assert m.tables.max_blocks == 8
    # every budget that passes the table-width check is admissible from a
    # drained pool
    assert m.can_admit(m.max_context)


def test_manager_reserves_garbage_page():
    mgr = PagedKVManager(num_pages=5, page_size=4, max_slots=2)
    assert mgr.alloc.num_pages == 4               # page 0 excluded
    mgr.admit(0, 16, 16)                          # map everything usable
    assert GARBAGE_PAGE not in mgr.alloc.pages_of(0)
    assert sorted(mgr.alloc.pages_of(0)) == [1, 2, 3, 4]
