"""Tests for the paper's core: AI estimation, scheduler, PIM models, system
simulators — including hypothesis property tests on the invariants."""
import numpy as np
import pytest
from _propcompat import given, settings, st

from repro.configs import get_config
from repro.configs.paper_models import GPT3_66B, GPT3_175B, LLAMA_65B
from repro.core import ai, pim
from repro.core.calibration import calibrate_alpha_model
from repro.core.scheduler import FC_PIM, FC_PU, PapiScheduler
from repro.core.system import (
    calibrate_alpha_system,
    compare_systems,
    simulate_decode,
)
from repro.core.traces import generate_trace


# ---------------------------------------------------------------------------
# §5.1 arithmetic intensity
# ---------------------------------------------------------------------------

class TestAI:
    def test_eq1_matches_paper_example(self):
        """§3.3: GPT-3-ish FC at batch 4, spec 8 has AI ~= 31.7 FLOP/B."""
        got = ai.fc_ai_exact(32, 7168)  # OPT-30B h
        assert 28 < got < 33

    @given(st.integers(1, 512), st.integers(1024, 16384))
    @settings(max_examples=200, deadline=None)
    def test_eq2_upper_bounds_eq1(self, m, h):
        """AI_exact < m always, and -> m as h -> inf (Eq. 2 derivation)."""
        exact = ai.fc_ai_exact(m, h)
        assert exact < m + 1e-9

    @given(st.integers(1, 128))
    @settings(max_examples=50, deadline=None)
    def test_eq2_error_small_for_large_h(self, m):
        """For GPT-3 175B's h=12288 the Eq.2 estimate is within 10% (Fig 6)."""
        exact = ai.fc_ai_exact(m, 12288)
        est = ai.fc_ai_estimate(m, 1)
        assert abs(est - exact) / exact < 0.10

    def test_eq2_error_largest_for_smallest_h(self):
        """qwen2-0.5b (h=896) stresses the large-h assumption hardest."""
        errs = {
            name: abs(ai.fc_ai_estimate(64, 1) - ai.fc_ai_exact(64, get_config(name).d_model))
            / ai.fc_ai_exact(64, get_config(name).d_model)
            for name in ("qwen2-0.5b", "command-r-plus-104b")
        }
        assert errs["qwen2-0.5b"] > errs["command-r-plus-104b"]

    def test_moe_effective_parallelism(self):
        """§6.5: per-expert parallelism is RLP*TLP*top_k/E."""
        olmoe = get_config("olmoe-1b-7b")
        dense = get_config("granite-8b")
        assert ai.effective_parallelism(olmoe, 64, 2) == 64 * 2 * 8 / 64
        assert ai.effective_parallelism(dense, 64, 2) == 128


# ---------------------------------------------------------------------------
# §5.2 scheduler
# ---------------------------------------------------------------------------

class TestScheduler:
    def _sched(self, alpha=32.0, tlp=2):
        return PapiScheduler(get_config("granite-8b"), alpha=alpha, tlp=tlp)

    def test_initial_schedule(self):
        s = self._sched()
        assert s.initial_schedule(64, 2) == FC_PU        # 128 > 32
        assert s.initial_schedule(8, 2) == FC_PIM        # 16 <= 32

    def test_eos_counting_drives_reschedule(self):
        """RLP decays via <eos> counts until the FC kernel flips to PIM."""
        s = self._sched(alpha=32.0, tlp=1)
        s.initial_schedule(64, 1)
        assert s.fc_assignment == FC_PU
        eos, other = 2, 0
        flipped_at = None
        for it in range(40):
            toks = [eos] * 2 + [other] * (s.rlp - 2)
            s.observe_outputs(toks)
            if s.fc_assignment == FC_PIM and flipped_at is None:
                flipped_at = it
        assert flipped_at is not None
        assert s.num_reschedules >= 1

    def test_tlp_register_update(self):
        s = self._sched(alpha=32.0, tlp=1)
        s.initial_schedule(16, 1)
        assert s.fc_assignment == FC_PIM
        s.set_tlp(8)                      # host bumps speculation length
        s.observe_counts(finished=0)
        assert s.fc_assignment == FC_PU   # 16*8 = 128 > 32

    def test_continuous_batching_admission(self):
        s = self._sched(alpha=32.0, tlp=1)
        s.initial_schedule(16, 1)
        s.observe_counts(finished=0, admitted=32)
        assert s.rlp == 48
        assert s.fc_assignment == FC_PU

    @given(st.integers(1, 256), st.integers(1, 8),
           st.floats(0.5, 256.0))
    @settings(max_examples=200, deadline=None)
    def test_decision_monotone_in_parallelism(self, rlp, tlp, alpha):
        """Property: the decision is a threshold function of RLP*TLP."""
        s = PapiScheduler(get_config("granite-8b"), alpha=alpha, tlp=tlp)
        s.rlp = rlp
        want = FC_PU if rlp * tlp > alpha else FC_PIM
        assert s._decide() == want

    def test_attention_always_pinned(self):
        s = self._sched()
        assert s.attention_assignment == "attn_pim"

    @pytest.mark.parametrize("name", ["granite-8b", "olmoe-1b-7b"])
    @pytest.mark.parametrize("tlp", [1, 2, 4, 8])
    def test_crossover_sweep_around_calibrated_alpha(self, name, tlp):
        """(rlp, tlp) grid straddling the *calibrated* alpha: the decision
        must be exactly the threshold function of effective parallelism,
        with a single monotone pim->pu flip as parallelism rises (the MoE
        top_k/E correction shifts the flip point, §6.5)."""
        cfg = get_config(name)
        alpha = calibrate_alpha_model(cfg)
        assert alpha > 0
        # effective parallelism = rlp*tlp*factor; pick rlps bracketing the
        # boundary rlp = alpha/(tlp*factor) plus the extremes
        factor = ai.effective_parallelism(cfg, 1, 1)
        boundary = alpha / (tlp * factor)
        rlps = sorted({1, 2, 512} | {
            max(1, int(boundary) + d) for d in (-2, -1, 0, 1, 2)})
        decisions = []
        for rlp in rlps:
            s = PapiScheduler(cfg, alpha=alpha, tlp=tlp)
            s.rlp = rlp
            got = s._decide()
            eff = ai.effective_parallelism(cfg, rlp, tlp)
            assert got == (FC_PU if eff > alpha else FC_PIM), (
                f"{name}: rlp={rlp} tlp={tlp} eff={eff} alpha={alpha}")
            decisions.append((eff, got))
        # monotone: sorted by effective parallelism, pu never reverts to pim
        decisions.sort(key=lambda t: t[0])
        flags = [d == FC_PU for _, d in decisions]
        assert flags == sorted(flags), (
            f"{name} tlp={tlp}: non-monotone flip sequence {decisions}")
        # the grid actually exercises both sides of the boundary
        assert flags[0] is False and flags[-1] is True

    def test_observe_counts_accepts_arrays(self):
        """Regression: the fused engine hands device bundles (bool / int
        arrays, numpy scalars) straight to observe_counts — they must sum
        arithmetically, not truthiness-collapse."""
        s = self._sched(alpha=32.0, tlp=1)
        s.initial_schedule(40, 1)
        s.observe_counts(np.array([True, False, True, True]),
                         admitted=np.int64(2))
        assert s.rlp == 40 - 3 + 2
        s.observe_counts(np.zeros(8, dtype=np.int32))
        assert s.rlp == 39
        s.observe_counts(np.array([5, 4]), admitted=np.array([1, 1]))
        assert s.rlp == 39 - 9 + 2
        assert s.fc_assignment == FC_PIM  # 32*1 <= alpha: flipped to PIM


# ---------------------------------------------------------------------------
# §6 PIM models
# ---------------------------------------------------------------------------

class TestPIM:
    def test_fig7_energy_fractions(self):
        assert pim.energy_breakdown(1)["dram"] == pytest.approx(0.967, abs=0.003)
        assert pim.energy_breakdown(64)["dram"] == pytest.approx(0.331, abs=0.005)

    def test_fig7c_power_claims(self):
        # 1P1B exceeds the budget at reuse=1; 1P2B fits; 4P1B fits iff r>=4.
        assert pim.ATTACC.power_at(1) > pim.HBM_POWER_BUDGET_W
        assert pim.HBM_PIM.power_at(1) < pim.HBM_POWER_BUDGET_W
        assert pim.FC_PIM.power_at(4) <= pim.HBM_POWER_BUDGET_W + 1
        assert pim.FC_PIM.power_at(3) > pim.HBM_POWER_BUDGET_W

    def test_eq34_area_constraint(self):
        # 4P1B: 96 banks/die, within the 121 mm^2 die budget (Eq. 4).
        assert pim.FC_PIM.banks_per_die == 96
        assert pim.FC_PIM.area_per_die_mm2() <= pim.A_DIE_MM2
        # capacity consequence the paper states: FC-PIM stacks hold 12 GB.
        assert pim.FC_PIM.capacity_bytes == pytest.approx(12e9)

    def test_fig4_fc_crossover(self):
        """PIM wins the FC kernel at low parallelism, GPU at high (Fig. 4)."""
        h = 7168
        lo_pim = pim.FC_PIM.gemv_time(4, h, h // 30)
        lo_gpu = pim.gpu_fc_time(4, h, h)
        hi_pim = pim.FC_PIM.gemv_time(512, h, h // 30)
        hi_gpu = pim.gpu_fc_time(512, h, h)
        assert lo_pim < lo_gpu
        assert hi_gpu < hi_pim

    @given(st.integers(1, 512))
    @settings(max_examples=50, deadline=None)
    def test_gemv_time_monotone(self, m):
        t1 = pim.FC_PIM.gemv_time(m, 4096, 4096)
        t2 = pim.FC_PIM.gemv_time(m + 1, 4096, 4096)
        assert t2 >= t1 - 1e-15


# ---------------------------------------------------------------------------
# §7 end-to-end simulators
# ---------------------------------------------------------------------------

class TestSystem:
    @pytest.fixture(scope="class")
    def results(self):
        trace = generate_trace("creative-writing", 16, seed=0)
        return compare_systems(LLAMA_65B, trace, batch_size=16, spec_len=2)

    def test_papi_fastest(self, results):
        papi = results["papi"].time_s
        for name, r in results.items():
            assert papi <= r.time_s * 1.0001, name

    def test_ordering_matches_paper(self, results):
        """Fig. 8 ordering: papi < a100+attacc ~ a100+hbmpim << attacc-only."""
        assert results["a100_attacc"].time_s < results["attacc_only"].time_s
        assert results["papi"].time_s < results["a100_attacc"].time_s

    def test_energy_papi_beats_gpu_baseline(self, results):
        assert (results["papi"].energy_per_token
                < results["a100_attacc"].energy_per_token)

    def test_scheduler_actually_reschedules(self):
        """With decaying RLP the PAPI run must flip assignments >= once."""
        trace = generate_trace("creative-writing", 48, seed=1)
        r = simulate_decode("papi", LLAMA_65B, trace, 48, 1)
        assert r.reschedules >= 1

    def test_headline_speedups_in_band(self):
        """Mean speedups over the paper's setting grid land near the paper's
        reported 1.8x / 1.9x (exact-match band documented in EXPERIMENTS.md)."""
        trace = generate_trace("creative-writing", 64, seed=0)
        ratios = {"a100_attacc": [], "a100_hbmpim": [], "attacc_only": []}
        for cfg in (LLAMA_65B, GPT3_66B, GPT3_175B):
            for bs in (4, 16, 64):
                for sl in (1, 2, 4):
                    res = compare_systems(
                        cfg, trace[:bs], bs, sl,
                        systems=("papi",) + tuple(ratios),
                    )
                    papi = res["papi"].time_s
                    for s in ratios:
                        ratios[s].append(res[s].time_s / papi)
        mean = {s: float(np.mean(v)) for s, v in ratios.items()}
        assert 1.5 < mean["a100_attacc"] < 2.1      # paper: 1.8
        assert 1.5 < mean["a100_hbmpim"] < 2.2      # paper: 1.9
        assert mean["attacc_only"] > 4.0            # paper: 11.1 (see §Repro)

    def test_alpha_calibration_sane(self):
        a = calibrate_alpha_system(LLAMA_65B)
        assert 4 < a < 512
