"""Paged KV-cache serving tests: token identity with the dense engine,
the paged-only long-context scenario, and the block-table Pallas kernel.

The contract under test (ISSUE 3 + ISSUE 5 acceptance):
  * on any workload BOTH layouts can hold, the paged engine emits token
    streams identical to the dense engine — greedy and speculative;
  * a request whose prompt+generation exceeds the dense per-slot capacity
    completes under the paged layout (pooled pages, no uniform slot cap);
  * the paged flash-decode kernel is bit-identical to the dense kernel on
    identical KV contents (same body, block_k = page_size) — for every
    query-window width t (plain decode, TLP>1 verify, chunk waves);
  * under attn_pim the WINDOWED kernel serves speculative verify and
    chunked prefill too, token-identically to the XLA engines, and
    `gather_kv_pages` never traces (poison-tested);
  * prompt truncation is GONE: prompts longer than the prefill window are
    chunked through it and complete in full;
  * the pool drains: after all requests finish, every page is free again.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import decode_attention, paged_decode_attention
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def draft_model():
    cfg = get_config("qwen2-0.5b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(9))


# eos that random-init weights essentially never argmax to — keeps the
# generation lengths deterministic across layouts and long for the
# long-context scenario
NO_EOS = get_config("qwen2-0.5b").reduced().vocab_size - 1

# mixed-length workload: short and long prompts, staggered budgets
MIXED = [([3 + i, 5, 7, 11][: 2 + i % 3], 3 + 4 * i) for i in range(6)]


def _run(cfg, params, reqs, **kw):
    defaults = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=1, debug_invariants=True)
    defaults.update(kw)
    eng = PapiEngine(cfg, params, **defaults)
    for i, (prompt, n) in enumerate(reqs):
        eng.submit(ServeRequest(i, list(prompt), max_new_tokens=n))
    results = eng.run(max_iterations=500)
    streams = {r.req_id: (r.tokens, r.finished_reason) for r in results}
    return streams, eng


def _assert_drained(eng):
    eng.kv.alloc.check()
    assert eng.kv.alloc.mapped_count == 0
    assert eng.kv.alloc.reserved_unmapped == 0
    assert eng.kv.alloc.free_count == eng.kv.alloc.num_pages


def test_paged_greedy_identical_to_dense(small_model):
    cfg, params = small_model
    want, _ = _run(cfg, params, MIXED)
    got, eng = _run(cfg, params, MIXED, kv_layout="paged", page_size=16)
    assert got == want
    _assert_drained(eng)


def test_paged_speculative_identical_to_dense(small_model, draft_model):
    """Draft/verify/accept + device-side cache rewind over block tables:
    same accepted windows, same tokens — and the host-side page rewind
    returns every page by drain time."""
    cfg, params = small_model
    want, _ = _run(cfg, params, MIXED, spec_len=3, draft=draft_model)
    got, eng = _run(cfg, params, MIXED, spec_len=3, draft=draft_model,
                    kv_layout="paged", page_size=8)
    assert got == want
    _assert_drained(eng)


def test_paged_unfused_host_loop_matches_fused(small_model, draft_model):
    """The legacy per-step host loop drives the same paged cache."""
    cfg, params = small_model
    reqs = MIXED[:3]
    want, _ = _run(cfg, params, reqs, spec_len=3, draft=draft_model,
                   kv_layout="paged", page_size=8)
    got, _ = _run(cfg, params, reqs, spec_len=3, draft=draft_model,
                  kv_layout="paged", page_size=8, fused=False)
    assert got == want


def test_paged_completes_request_beyond_dense_slot_capacity(small_model):
    """THE paged-only scenario: prompt + generation far exceeds the
    64-token dense slot, but fits the page pool — the dense engine clamps
    the budget, the paged engine completes it in full."""
    cfg, params = small_model
    prompt = [3, 5, 7, 11, 13, 17]
    want_new = 100
    assert len(prompt) + want_new > 64

    dense, _ = _run(cfg, params, [(prompt, want_new)], eos_token=NO_EOS)
    assert len(dense[0][0]) < want_new        # clamped to the slot budget

    paged, eng = _run(cfg, params, [(prompt, want_new)], eos_token=NO_EOS,
                      kv_layout="paged", page_size=16)
    tokens, reason = paged[0]
    assert len(tokens) == want_new and reason == "length"
    # and the dense stream is a prefix of the paged one (same model path)
    assert tokens[: len(dense[0][0])] == dense[0][0]
    assert eng.kv.alloc.watermark >= eng.kv.pages_for(len(prompt) + want_new)
    _assert_drained(eng)


def test_paged_admission_defers_until_pages_free(small_model):
    """More demand than the pool holds at once: admission must defer (not
    reject), keep order, and finish everyone."""
    cfg, params = small_model
    reqs = [([3 + i, 5, 7], 40) for i in range(6)]
    got, eng = _run(cfg, params, reqs, eos_token=NO_EOS, cache_capacity=32,
                    kv_layout="paged", page_size=8)
    assert sorted(got) == list(range(6))
    assert all(len(t) == 40 and r == "length" for t, r in got.values())
    _assert_drained(eng)


def test_paged_set_spec_len_widen_rebudgets_or_clamps(small_model,
                                                      draft_model):
    """Widening the speculative window mid-run must re-budget live slots'
    page reservations — and clamp the window instead of letting the
    per-iteration ensure() blow up with MemoryError when the pool is
    already fully promised (regression: set_spec_len used to leave the old
    reservations in place and the next decode iteration crashed)."""
    cfg, params = small_model
    eng = PapiEngine(cfg, params, max_slots=2, cache_capacity=32,
                     prefill_len=8, alpha=6.0, eos_token=NO_EOS,
                     spec_len=2, draft=draft_model,
                     kv_layout="paged", page_size=4)
    # pool = 2*32/4 = 16 usable pages; each request reserves
    # pages_for(3 + 27 + 2) = 8 — the two together promise the whole pool
    for i in range(2):
        eng.submit(ServeRequest(i, [3, 5, 7], max_new_tokens=27))
    eng.run(max_iterations=2, abort_in_flight=False)
    assert eng.active_slots == [0, 1]
    assert eng.kv.alloc.available == 0
    eng.set_spec_len(6)             # nothing uncommitted: must clamp
    assert eng.spec_len == 2
    res = eng.run(max_iterations=300)
    assert sorted(r.req_id for r in res) == [0, 1]
    assert all(len(r.tokens) == 27 and r.finished_reason == "length"
               for r in res)
    _assert_drained(eng)

    # with headroom the widen goes through and the wider window is served
    eng2 = PapiEngine(cfg, params, max_slots=2, cache_capacity=64,
                      prefill_len=8, alpha=6.0, eos_token=NO_EOS,
                      spec_len=2, draft=draft_model,
                      kv_layout="paged", page_size=4)
    eng2.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=20))
    eng2.run(max_iterations=2, abort_in_flight=False)
    eng2.set_spec_len(6)
    assert eng2.spec_len == 6
    res2 = eng2.run(max_iterations=300)
    assert len(res2[0].tokens) == 20 and res2[0].finished_reason == "length"
    _assert_drained(eng2)

    # table width also caps the window: a slot admitted flush against
    # max_blocks has no rows left, so the widen clamps even though the
    # POOL has plenty of free pages
    eng3 = PapiEngine(cfg, params, max_slots=2, cache_capacity=64,
                      prefill_len=8, alpha=6.0, eos_token=NO_EOS,
                      spec_len=2, draft=draft_model,
                      kv_layout="paged", page_size=4, max_blocks=6)
    eng3.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=40))
    eng3.run(max_iterations=2,      # admitted clamped to the 24-token table
             abort_in_flight=False)
    assert eng3.kv.alloc.available > 0
    eng3.set_spec_len(6)
    assert eng3.spec_len == 2
    res3 = eng3.run(max_iterations=300)[0]
    assert res3.finished_reason == "length" and len(res3.tokens) == 19
    _assert_drained(eng3)


def test_paged_attn_pim_kernel_path_matches_xla(small_model):
    """attn_pim=True routes paged plain decode through the block-table
    Pallas kernel; tokens must match the XLA gather path and the dense
    engine."""
    cfg, params = small_model
    reqs = MIXED[:3]
    want, _ = _run(cfg, params, reqs)
    got, _ = _run(cfg, params, reqs, kv_layout="paged", page_size=16,
                  attn_pim=True)
    assert got == want


def test_paged_speculative_attn_pim_matches_dense(small_model, draft_model):
    """THE ISSUE 5 path: speculative verify windows (TLP=3) over the paged
    layout through the WINDOWED block-table kernel — draft steps, verify
    windows, accept/rewind — must emit the dense XLA engine's exact
    tokens, and drain the pool."""
    cfg, params = small_model
    want, _ = _run(cfg, params, MIXED, spec_len=3, draft=draft_model)
    got, eng = _run(cfg, params, MIXED, spec_len=3, draft=draft_model,
                    kv_layout="paged", page_size=8, attn_pim=True)
    assert got == want
    _assert_drained(eng)


@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_chunked_prefill_attn_pim_matches_xla(small_model, kv_layout):
    """Chunked admission under attn_pim: every chunk wave is a query
    window through the windowed kernel (t = prefill_len, per-slot masked
    writes), and the streams must match the XLA-path engine's — long and
    short prompts alike."""
    cfg, params = small_model
    kw = dict(kv_layout="paged", page_size=8) if kv_layout == "paged" else {}
    reqs = [(list(range(3, 3 + 20)), 4), ([3, 5], 4),
            (list(range(5, 5 + 30)), 3)]
    want, _ = _run(cfg, params, reqs, **kw)
    got, _ = _run(cfg, params, reqs, attn_pim=True, **kw)
    assert got == want


def test_no_page_gather_traced_under_attn_pim(small_model, draft_model,
                                              monkeypatch):
    """ISSUE 5 acceptance: with attn_pim active, NO jitted decode / verify
    / chunk program may call `gather_kv_pages` — the paged kernel resolves
    pages inside its index_map.  Poison the gather and run the full
    gauntlet (chunked admission, plain decode, speculative draft+verify):
    a single traced gather raises."""
    from repro.models import layers

    def boom(pages, tables):
        raise AssertionError(
            "gather_kv_pages traced on the attn_pim hot path")

    cfg, params = small_model
    reqs = [(list(range(3, 3 + 20)), 5), ([3, 5, 7], 6)]
    kw = dict(kv_layout="paged", page_size=8, spec_len=3, draft=draft_model,
              eos_token=NO_EOS)
    want, _ = _run(cfg, params, reqs, **kw)          # XLA gather path
    monkeypatch.setattr(layers, "gather_kv_pages", boom)
    got, eng = _run(cfg, params, reqs, attn_pim=True, **kw)
    assert got == want
    _assert_drained(eng)


def test_paged_iter_stats_surface_pool_state(small_model):
    cfg, params = small_model
    _, eng = _run(cfg, params, MIXED, kv_layout="paged", page_size=16)
    busy = [s for s in eng.stats if s.new_tokens > 0]
    assert busy and any(s.kv_pages_used > 0 for s in busy)
    assert max(s.kv_page_watermark for s in eng.stats) == eng.kv.alloc.watermark
    assert all(0.0 <= s.kv_fragmentation <= 1.0 for s in eng.stats)
    # dense engines report zeros (fields exist but stay inert)
    _, dense_eng = _run(cfg, params, MIXED[:2])
    assert all(s.kv_pages_used == 0 for s in dense_eng.stats)


@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_long_prompts_complete_untruncated(small_model, kv_layout):
    """`p = min(len(prompt), prefill_len)` used to silently drop the prompt
    head; admission now CHUNKS any prompt through the prefill window, so
    long prompts complete in full — no truncation flag, no warning, and the
    streams match an engine whose window holds each prompt one-shot."""
    cfg, params = small_model
    kw = {"page_size": 16} if kv_layout == "paged" else {}

    def run(prefill_len):
        eng = PapiEngine(cfg, params, max_slots=2, cache_capacity=64,
                         prefill_len=prefill_len, alpha=6.0, eos_token=1,
                         kv_layout=kv_layout, **kw)
        eng.submit(ServeRequest(0, list(range(3, 3 + 20)), max_new_tokens=3))
        eng.submit(ServeRequest(1, [3, 5], max_new_tokens=3))
        eng.submit(ServeRequest(2, list(range(5, 5 + 30)), max_new_tokens=3))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            results = {r.req_id: r for r in eng.run(max_iterations=100)}
        return results, caught

    results, caught = run(prefill_len=8)      # 20- and 30-token prompts chunk
    oneshot, _ = run(prefill_len=32)          # every prompt fits one window
    assert not any("prefill_len" in str(w.message) for w in caught)
    for i in range(3):
        assert results[i].tokens == oneshot[i].tokens


@pytest.mark.parametrize("t", [1, 2, 4])
def test_paged_kernel_bit_identical_to_dense_kernel(t):
    """Identical KV contents scattered across a shuffled page pool: the
    paged kernel (block-table index_map) must be BIT-identical to the
    dense kernel at block_k = page_size — the body is the same code.
    Holds for every query-window width: t=1 plain decode, t=2, and a
    spec-window t=4 (the windowed rows share the body's intra-window
    mask)."""
    b, nkv, g, hd, page, nblk = 3, 2, 4, 64, 32, 6
    S = page * nblk
    num_pages = b * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, nkv, t * g, hd), jnp.float32)
    kd = jax.random.normal(ks[1], (b, S, nkv, hd), jnp.float32)
    vd = jax.random.normal(ks[2], (b, S, nkv, hd), jnp.float32)
    lens = jnp.asarray([33, S, 7], jnp.int32)   # ragged: mid, full, tiny

    rng = np.random.default_rng(0)
    tables = rng.permutation(np.arange(1, num_pages)).reshape(b, nblk)
    kp = np.zeros((num_pages, page, nkv, hd), np.float32)
    vp = np.zeros_like(kp)
    for i in range(b):
        for blk in range(nblk):
            kp[tables[i, blk]] = np.asarray(kd)[i, blk * page:(blk + 1) * page]
            vp[tables[i, blk]] = np.asarray(vd)[i, blk * page:(blk + 1) * page]

    for skip in (True, False):
        want = decode_attention(q, kd, vd, lens, block_k=page,
                                interpret=True, block_skip=skip, q_rows=t)
        got = paged_decode_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                                     lens, jnp.asarray(tables),
                                     interpret=True, block_skip=skip,
                                     q_rows=t)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_windowed_kernel_matches_gather_oracle():
    """The windowed paged kernel vs the exact hot path it replaced:
    `gather_kv_pages` + the XLA windowed softmax.  Greedy-level agreement
    is what the engine gates assert; here the raw outputs must agree to
    f32 tolerance across ragged lens and a shuffled pool."""
    from repro.models.layers import (decode_attention_pim_paged,
                                     decode_attention_xla, gather_kv_pages)
    b, t, nh, nkv, hd, page, nblk = 3, 3, 4, 2, 32, 16, 5
    num_pages = b * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, t, nh, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, page, nkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, page, nkv, hd), jnp.float32)
    rng = np.random.default_rng(2)
    tables = jnp.asarray(
        rng.permutation(np.arange(1, num_pages)).reshape(b, nblk), jnp.int32)
    lens = jnp.asarray([t, 37, page * nblk], jnp.int32)   # min, mid, full
    pos = lens - t
    kg, vg = gather_kv_pages(kp, tables), gather_kv_pages(vp, tables)
    want = decode_attention_xla(q, kg, vg, cache_len=lens, q_offset=pos)
    got = decode_attention_pim_paged(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t", [1, 3])
def test_paged_kernel_garbage_table_entries_masked(t):
    """Entries at/past a request's last valid block may point anywhere
    (the engine points them at the garbage page) — they must not leak into
    the output, skipping on or off, single-query or windowed (a window's
    rows mask everything past their own position, so garbage never leaks
    backward into any row)."""
    b, nkv, g, hd, page, nblk = 2, 2, 2, 32, 16, 4
    num_pages = b * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, nkv, t * g, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, page, nkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, page, nkv, hd), jnp.float32)
    lens = jnp.asarray([20, 7], jnp.int32)      # 2 blocks / 1 block valid
    tables = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    scrubbed = tables.copy()
    scrubbed[0, 2:] = 0                         # beyond-len -> garbage page
    scrubbed[1, 1:] = 0
    for skip in (True, False):
        a = paged_decode_attention(q, kp, vp, lens, jnp.asarray(tables),
                                   interpret=True, block_skip=skip, q_rows=t)
        c = paged_decode_attention(q, kp, vp, lens, jnp.asarray(scrubbed),
                                   interpret=True, block_skip=skip, q_rows=t)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
