"""Serving engine integration tests: continuous batching, speculative
decoding losslessness, and PAPI's scheduler in the loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serving import PapiEngine, ServeRequest


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk_engine(cfg, params, **kw):
    defaults = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=1, debug_invariants=True)
    defaults.update(kw)
    return PapiEngine(cfg, params, **defaults)


def test_continuous_batching_completes_all(small_model):
    cfg, params = small_model
    eng = _mk_engine(cfg, params)
    for i in range(7):           # more requests than slots
        eng.submit(ServeRequest(i, [3 + i, 5, 7], max_new_tokens=6))
    results = eng.run(max_iterations=200)
    assert len(results) == 7
    assert sorted(r.req_id for r in results) == list(range(7))
    for r in results:
        assert 1 <= len(r.tokens) <= 6


def test_scheduler_flips_variant_as_rlp_decays(small_model):
    """Requests with staggered lengths: RLP decays, AI crosses alpha, and the
    FC path flips pu -> pim exactly as §5.2.2 prescribes."""
    cfg, params = small_model
    eng = _mk_engine(cfg, params, max_slots=8, alpha=4.0)
    for i in range(8):
        eng.submit(ServeRequest(i, [3, 5], max_new_tokens=2 + 3 * i))
    eng.run(max_iterations=200)
    variants = [s.fc_variant for s in eng.stats if s.rlp > 0]
    assert "pu" in variants     # 8 active > alpha=4
    assert "pim" in variants    # tail with < 4 active
    assert eng.scheduler.num_reschedules >= 1


def test_engine_output_matches_raw_decode(small_model):
    """The engine's greedy output for a single request must equal a direct
    prefill+decode loop on the raw model (slots/batching add nothing)."""
    cfg, params = small_model
    prompt = [3, 5, 7, 11]
    n_new = 5

    cache = init_cache(cfg, 1, 64)
    logits, cache = prefill(
        cfg, params,
        {"tokens": jnp.asarray([prompt], jnp.int32),
         "prompt_lens": jnp.asarray([len(prompt)], jnp.int32)},
        cache,
    )
    want = []
    tok = int(np.argmax(np.asarray(logits[0])))
    want.append(tok)
    for _ in range(n_new - 1):
        lg, cache = decode_step(cfg, params, cache, jnp.asarray([[tok]]))
        tok = int(np.argmax(np.asarray(lg[0, 0])))
        want.append(tok)

    eng = _mk_engine(cfg, params, max_slots=2)
    eng.submit(ServeRequest(0, prompt, max_new_tokens=n_new))
    res = eng.run(max_iterations=50)
    assert res[0].tokens[:n_new] == want[:len(res[0].tokens)]


def test_speculative_decoding_is_lossless(small_model):
    """Speculative output must equal plain greedy decoding token-for-token —
    the draft only changes *how fast* tokens appear, never *which* tokens."""
    cfg, params = small_model
    draft_cfg = get_config("qwen2-0.5b").reduced()
    draft_params = init_params(draft_cfg, jax.random.PRNGKey(9))
    prompt = [3, 5, 7]
    n_new = 8

    plain = _mk_engine(cfg, params, max_slots=2)
    plain.submit(ServeRequest(0, prompt, max_new_tokens=n_new))
    want = plain.run(max_iterations=100)[0].tokens

    spec = _mk_engine(cfg, params, max_slots=2, spec_len=3,
                      draft=(draft_cfg, draft_params))
    spec.submit(ServeRequest(0, prompt, max_new_tokens=n_new))
    got = spec.run(max_iterations=100)[0].tokens

    n = min(len(want), len(got))
    assert got[:n] == want[:n]


def test_speculative_with_perfect_draft_accepts_everything(small_model):
    """Draft == target => every proposal accepted => ~spec_len tokens/iter."""
    cfg, params = small_model
    eng = _mk_engine(cfg, params, max_slots=2, spec_len=4,
                     draft=(cfg, params))
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=12))
    res = eng.run(max_iterations=100)
    gen_iters = [s for s in eng.stats if s.new_tokens > 0]
    mean_acc = np.mean([s.accepted for s in gen_iters])
    assert mean_acc > 3.5        # near-perfect acceptance of 4-token windows
    assert len(res) == 1


def test_tlp_register_update_reflected(small_model):
    cfg, params = small_model
    eng = _mk_engine(cfg, params, alpha=6.0)
    eng.submit(ServeRequest(0, [3], max_new_tokens=4))
    eng.step()
    assert eng.scheduler.tlp == 1
    eng.set_spec_len(8)
    assert eng.scheduler.tlp == 8
    assert eng.scheduler.fc_assignment == "pu"   # 1*8 > 6


def test_attn_pim_path_matches_xla(small_model):
    """attn_pim=True routes plain decode through the Pallas flash-decode
    kernel (interpret mode on CPU); greedy tokens must match the XLA path."""
    cfg, params = small_model
    prompt = [3, 5, 7, 11]

    def run(**kw):
        eng = _mk_engine(cfg, params, **kw)
        eng.submit(ServeRequest(0, prompt, max_new_tokens=3))
        return eng.run(max_iterations=20)[0].tokens

    assert run(attn_pim=True) == run()


def test_step_counts_iteration_when_admission_defers(small_model):
    """`run(max_iterations=)` was a dead guard: step()'s no-active-slots
    early return skipped `iteration += 1`, so a queue whose head keeps
    deferring (paged pool busy) spun run() forever.  Every step must count,
    and run() must terminate at the bound."""
    cfg, params = small_model
    eng = _mk_engine(cfg, params, kv_layout="paged", page_size=16)
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=4))
    eng.kv.can_admit = lambda *_: False        # pool "busy" forever
    eng.step()
    eng.step()
    assert eng.iteration == 2                  # fails fast if steps vanish
    res = eng.run(max_iterations=7)            # used to livelock here
    assert eng.iteration == 7
    assert res == [] and eng.queue             # nothing served, queue intact


def test_dense_set_spec_len_widen_clamps_to_slab(small_model):
    """Dense mirror of the paged re-budget: admission reserved
    `prompt + budget + OLD window` slab rows per live slot, so widening the
    window mid-flight must clamp to the smallest live headroom — otherwise
    the verify step's dynamic_update_slice clamps at the capacity edge and
    silently corrupts earlier KV."""
    cfg, params = small_model
    draft_cfg = get_config("qwen2-0.5b").reduced()
    draft_params = init_params(draft_cfg, jax.random.PRNGKey(9))
    no_eos = cfg.vocab_size - 1

    plain = _mk_engine(cfg, params, max_slots=2, cache_capacity=24,
                       eos_token=no_eos)
    plain.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=19))
    want = plain.run(max_iterations=100)[0].tokens
    assert len(want) == 19                     # budget exactly fills the slab

    eng = _mk_engine(cfg, params, max_slots=2, cache_capacity=24,
                     eos_token=no_eos, spec_len=2,
                     draft=(draft_cfg, draft_params))
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=19))
    eng.run(max_iterations=2, abort_in_flight=False)
    assert eng.active_slots == [0]             # 3 + 19 + 2 = 24: zero headroom
    eng.set_spec_len(6)
    assert eng.spec_len == 2                   # clamped, not widened
    got = eng.run(max_iterations=200)[0].tokens
    assert got == want                         # lossless despite the attempt

    # with slab headroom the widen goes through
    eng2 = _mk_engine(cfg, params, max_slots=2, cache_capacity=40,
                      eos_token=no_eos, spec_len=2,
                      draft=(draft_cfg, draft_params))
    eng2.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=19))
    eng2.run(max_iterations=2, abort_in_flight=False)
    eng2.set_spec_len(6)
    assert eng2.spec_len == 6
    assert eng2.run(max_iterations=200)[0].tokens == want


@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_admission_never_mutates_caller_request(small_model, kv_layout):
    """_admit_wave used to write the clamped budget back into
    `req.max_new_tokens`, corrupting the caller's ServeRequest — a resubmit
    of the same object then ran with the previous engine's clamp.  The
    effective budget lives in engine slot state now."""
    cfg, params = small_model
    no_eos = cfg.vocab_size - 1
    kw = {"page_size": 4} if kv_layout == "paged" else {}
    req = ServeRequest(0, [3, 5, 7], max_new_tokens=500)   # over any budget

    def run_once():
        eng = _mk_engine(cfg, params, cache_capacity=16, eos_token=no_eos,
                         kv_layout=kv_layout, **kw)
        eng.submit(req)
        return eng.run(max_iterations=100)[0].tokens

    first = run_once()
    assert req.max_new_tokens == 500           # caller object pristine
    assert run_once() == first                 # resubmit: same clamp, stream
    assert req.max_new_tokens == 500


def test_pim_variant_runs_real_fc_gemv(small_model):
    """Force the pim path (interpret mode): the engine's decode must route
    FC projections through the Pallas kernel and still match the pu path."""
    cfg, params = small_model
    prompt = [3, 5, 7, 11]

    def run(alpha):
        eng = _mk_engine(cfg, params, alpha=alpha, pim_interpret=True)
        eng.submit(ServeRequest(0, prompt, max_new_tokens=3))
        return eng.run(max_iterations=20)[0].tokens

    pu_tokens = run(alpha=0.0)    # AI=1 > 0  -> pu every iteration
    pim_tokens = run(alpha=99.0)  # AI=1 < 99 -> pim every iteration
    assert pu_tokens == pim_tokens
