"""Sharding-rule logic tests (pure logic — no multi-device runtime needed)."""
import jax
import numpy as np
import pytest
from _propcompat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


class FakeMesh:
    """Shape-only stand-in for jax.sharding.Mesh (divisibility checks)."""
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh(data=16, model=16)
POD = FakeMesh(pod=2, data=16, model=16)


class TestFilterSpec:
    def test_divisible_kept(self):
        spec = shd.filter_spec_for_shape(P("data", "model"), (32, 64), MESH)
        assert tuple(spec) == ("data", "model")

    def test_indivisible_dropped(self):
        # qwen2's 14 heads can't shard over model=16
        spec = shd.filter_spec_for_shape(P(None, "model"), (8, 14), MESH)
        assert tuple(spec) == (None, None)

    def test_duplicate_axis_first_wins(self):
        # logits under SP: seq and vocab both -> model; first dim keeps it
        spec = shd.filter_spec_for_shape(
            P("data", "model", "model"), (32, 64, 128), MESH)
        assert tuple(spec) == ("data", "model", None)

    def test_tuple_axes(self):
        spec = shd.filter_spec_for_shape(
            P(("pod", "data"), "model"), (64, 32), POD)
        assert tuple(spec) == (("pod", "data"), "model")

    def test_tuple_axes_conflict(self):
        spec = shd.filter_spec_for_shape(
            P(("pod", "data"), "data"), (64, 32), POD)
        assert tuple(spec) == (("pod", "data"), None)

    @given(st.lists(st.integers(1, 512), min_size=1, max_size=4))
    @settings(max_examples=100, deadline=None)
    def test_property_result_always_divides(self, shape):
        spec = shd.filter_spec_for_shape(
            P(*(["model"] * len(shape))), tuple(shape), MESH)
        for dim, entry in zip(shape, tuple(spec)):
            if entry is not None:
                assert dim % MESH.shape[entry] == 0

    @given(st.lists(st.sampled_from(["data", "model", None]),
                    min_size=1, max_size=5))
    @settings(max_examples=100, deadline=None)
    def test_property_no_duplicate_axes(self, entries):
        spec = shd.filter_spec_for_shape(
            P(*entries), tuple([256] * len(entries)), MESH)
        used = [e for e in tuple(spec) if e is not None]
        assert len(used) == len(set(used))


class TestAxisRules:
    def test_noop_outside_context(self):
        x = jax.numpy.ones((4, 4))
        assert shd.shard(x, "batch", "seq") is x

    def test_rules_resolve(self):
        with shd.axis_rules({"batch": "data", "seq": "model"}):
            spec = shd.logical_to_spec(("batch", "seq", None))
        assert tuple(spec) == ("data", "model", None)

    def test_rule_tables_cover_model_logical_names(self):
        """Every logical name the models emit must resolve in both tables."""
        from repro.models import cache_logical_axes, param_logical_axes
        from repro.configs import ASSIGNED
        names = set()
        for cfg in ASSIGNED:
            for t in (param_logical_axes(cfg),):
                for leaf in jax.tree.leaves(
                        t, is_leaf=lambda x: isinstance(x, tuple)):
                    names.update(a for a in leaf if isinstance(a, str))
            if cfg.has_decode_step:
                for leaf in jax.tree.leaves(
                        cache_logical_axes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple)):
                    names.update(a for a in leaf if isinstance(a, str))
        for table in (shd.train_rules(), shd.serve_rules(),
                      shd.train_rules(multi_pod=True),
                      shd.serve_rules(multi_pod=True, long_context=True)):
            missing = {n for n in names if n != "scan" and n not in table}
            assert not missing, missing
