"""Speculative accept/rewind: the device-side vectorized logic must match
the seed's per-slot Python reference, the fused engine must reproduce the
host-looped engine token-for-token, and the KV cache position must never
regress below its pre-window value."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcompat import given, settings, st

from repro.configs import get_config
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest
from repro.serving.sampler import accept_speculative


def _reference_accept(window: np.ndarray, target: np.ndarray):
    """The seed's per-slot Python loop (engine.py @ PR 0)."""
    b, k = window.shape
    accepted = np.zeros(b, np.int64)
    out = np.zeros((b, k), np.int32)
    for s in range(b):
        n = 0
        while n < k - 1 and window[s, n + 1] == target[s, n]:
            n += 1
        accepted[s] = n + 1                       # +1: free token
        out[s, : n + 1] = target[s, : n + 1]
    return out, accepted


@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_accept_matches_python_reference(b, k, seed):
    """Random draft/target agreement patterns: a tiny vocab makes partial
    prefix matches frequent, exercising every accepted-count in [1, k]."""
    rng = np.random.default_rng(seed)
    window = rng.integers(0, 3, (b, k)).astype(np.int32)
    target = rng.integers(0, 3, (b, k)).astype(np.int32)
    out, acc = accept_speculative(jnp.asarray(window), jnp.asarray(target))
    ref_out, ref_acc = _reference_accept(window, target)
    np.testing.assert_array_equal(np.asarray(acc), ref_acc)
    np.testing.assert_array_equal(np.asarray(out), ref_out)
    assert np.all(np.asarray(acc) >= 1) and np.all(np.asarray(acc) <= k)


def test_accept_full_and_zero_agreement():
    window = np.array([[5, 7, 9, 11]], np.int32)
    # full agreement on the 3 proposals: all 4 target tokens accepted
    target_full = np.array([[7, 9, 11, 13]], np.int32)
    out, acc = accept_speculative(jnp.asarray(window),
                                  jnp.asarray(target_full))
    assert int(acc[0]) == 4
    np.testing.assert_array_equal(np.asarray(out)[0], [7, 9, 11, 13])
    # zero agreement: only the free correction token accepted
    target_none = np.array([[1, 1, 1, 1]], np.int32)
    out, acc = accept_speculative(jnp.asarray(window),
                                  jnp.asarray(target_none))
    assert int(acc[0]) == 1
    np.testing.assert_array_equal(np.asarray(out)[0], [1, 0, 0, 0])


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    draft_params = init_params(cfg, jax.random.PRNGKey(9))
    return cfg, params, draft_params


def _mk(cfg, params, draft_params, **kw):
    defaults = dict(max_slots=2, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=1, spec_len=3,
                    draft=(cfg, draft_params))
    defaults.update(kw)
    return PapiEngine(cfg, params, **defaults)


def test_fused_engine_matches_host_reference(small_model):
    """The scan-fused device iteration and the seed's per-step host loop must
    emit identical tokens for identical request streams."""
    cfg, params, draft_params = small_model
    reqs = [([3, 5, 7], 9), ([4, 6], 7), ([2, 3, 5, 7, 11], 8)]

    def run(fused):
        eng = _mk(cfg, params, draft_params, fused=fused)
        for i, (prompt, n) in enumerate(reqs):
            eng.submit(ServeRequest(i, list(prompt), max_new_tokens=n))
        res = {r.req_id: r for r in eng.run(max_iterations=100)}
        return eng, res

    eng_f, res_f = run(fused=True)
    eng_h, res_h = run(fused=False)
    assert sorted(res_f) == sorted(res_h)
    for rid in res_f:
        assert res_f[rid].tokens == res_h[rid].tokens, rid
        assert res_f[rid].finished_reason == res_h[rid].finished_reason

    # the whole point: the fused decode iteration costs ONE host round-trip,
    # the host-looped reference costs spec_len + 1
    f_iters = [s for s in eng_f.stats if s.new_tokens > 0]
    h_iters = [s for s in eng_h.stats if s.new_tokens > 0]
    assert min(s.transfers for s in f_iters) == 1
    assert max(s.transfers for s in h_iters) >= eng_h.spec_len + 1


def test_cache_pos_never_regresses_below_window_start(small_model):
    """After every speculative step, each still-active slot's cache position
    advanced by accepted in [1, spec_len] — the rewind never undershoots the
    pre-window position."""
    cfg, params, draft_params = small_model
    k = 3
    eng = _mk(cfg, params, draft_params, spec_len=k)
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=12))
    eng.submit(ServeRequest(1, [4, 6, 8, 10], max_new_tokens=10))
    steps = 0
    while (eng.queue or eng.active_slots) and steps < 60:
        active_before = set(eng.active_slots)
        pos_before = np.asarray(jax.device_get(eng.cache["pos"]))
        eng.step()
        steps += 1
        pos_after = np.asarray(jax.device_get(eng.cache["pos"]))
        for s in active_before & set(eng.active_slots):
            adv = int(pos_after[s]) - int(pos_before[s])
            assert 1 <= adv <= k, (s, adv)


def test_admit_rejects_oversized_prompts(small_model):
    """A request whose prompt + speculative window cannot fit the slot's KV
    capacity is rejected up-front instead of silently emitting a 1-token
    'length' result."""
    cfg, params, _ = small_model
    eng = PapiEngine(cfg, params, max_slots=2, cache_capacity=10,
                     prefill_len=8, alpha=6.0, eos_token=1, spec_len=4)
    eng.submit(ServeRequest(0, list(range(3, 20)), max_new_tokens=5))
    # capacity 10 - full 17-token prompt - spec window 4 < 1 -> rejected
    # honestly (chunked prefill never truncates, so the slab budget is
    # checked against the WHOLE prompt)
    res = eng.run(max_iterations=10)
    assert len(res) == 1
    assert res[0].finished_reason == "rejected"
    assert res[0].tokens == []

    # a short prompt still fits and gets a clamped-but-positive budget
    eng2 = PapiEngine(cfg, params, max_slots=2, cache_capacity=10,
                      prefill_len=4, alpha=6.0, eos_token=1, spec_len=1)
    eng2.submit(ServeRequest(1, [3, 5], max_new_tokens=50))
    res2 = eng2.run(max_iterations=40)
    assert len(res2) == 1
    assert res2[0].finished_reason in ("eos", "length")
    assert 1 <= len(res2[0].tokens) <= 10


def test_instant_finish_frees_slot_within_same_step(small_model):
    """A request that finishes at admission (1-token budget) must hand its
    slot to the next queued request in the SAME step — admission runs in
    waves until no slot is instantly freed."""
    cfg, params, _ = small_model
    eng = PapiEngine(cfg, params, max_slots=1, cache_capacity=64,
                     prefill_len=8, alpha=6.0, eos_token=1, spec_len=1)
    eng.submit(ServeRequest(0, [3, 5], max_new_tokens=1))
    eng.submit(ServeRequest(1, [4, 6], max_new_tokens=1))
    eng.submit(ServeRequest(2, [5, 7], max_new_tokens=4))
    eng.step()
    # both 1-token requests completed and the third occupies the slot
    done = sorted(r.req_id for r in eng.results)
    assert done == [0, 1]
    assert eng.slot_req[0] is not None and eng.slot_req[0].req_id == 2


def test_scheduler_accepts_array_counts():
    from repro.core.scheduler import PapiScheduler
    s = PapiScheduler(get_config("granite-8b"), alpha=32.0, tlp=1)
    s.initial_schedule(16, 1)
    s.observe_counts(np.array([True, False, True, False]), admitted=1)
    assert s.rlp == 15
    s.observe_counts(np.int64(2), admitted=np.int64(0))
    assert s.rlp == 13
