"""Engine telemetry layer (src/repro/serving/telemetry.py):

  * the `Tracer` ring keeps the NEWEST events and counts what it dropped;
    aggregate counters stay exact under truncation;
  * a traced engine run emits only vocabulary kinds, with non-decreasing
    iteration stamps, and `tools/trace_report.py`'s mirrored vocabulary
    stays in sync with `telemetry.EVENT_KINDS`;
  * the chrome export survives a json round trip with valid ph/ts/pid and
    carries the aggregate tables under "papi"; the Prometheus snapshot is
    line-parseable text exposition;
  * the per-program timing table is hand-countable on a single greedy
    request (1 prefill dispatch + max_new-1 decode dispatches);
  * tracing is observation only: serve() token streams are BIT-IDENTICAL
    traced vs untraced, and the NullTracer default keeps every hook a
    no-op;
  * scheduler events carry the AI estimate AND the alpha threshold, flips
    match `num_reschedules`; degraded/fault events match the engine's own
    counts on a spec+paged+faults run; a watchdog stall lands a final
    `stall` event before EngineStallError propagates;
  * page map/unmap/reserve events balance to zero on a drained pool;
  * `latency_summary` reports per-metric sample counts and tpot_s only
    over requests with >= 2 tokens.
"""
import json
import re
import sys
from pathlib import Path

import jax
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (EngineStallError, FaultInjector, PapiEngine,
                           ServeRequest, Tracer, export_chrome, export_jsonl,
                           export_prometheus, latency_summary, write_trace)
from repro.serving.telemetry import (EVENT_KINDS, NULL_TRACER,
                                     format_program_key)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import trace_report  # noqa: E402  (tools/ is not a package)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def draft_model():
    cfg = get_config("qwen2-0.5b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(9))


NO_EOS = get_config("qwen2-0.5b").reduced().vocab_size - 1


def _engine(cfg, params, **kw):
    defaults = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=NO_EOS, fused=True,
                    debug_invariants=True)
    defaults.update(kw)
    return PapiEngine(cfg, params, **defaults)


def _submit_all(eng, n=3, max_new=6):
    for i in range(n):
        eng.submit(ServeRequest(i, [3 + i, 5, 7], max_new_tokens=max_new))


# ------------------------------------------------------------- ring buffer

def test_ring_truncation_keeps_newest_and_counts_dropped():
    tr = Tracer(capacity=10)
    for i in range(25):
        tr.emit("submit", iteration=i, req_id=i, prompt_len=3, max_new=4)
    events = list(tr.events)
    assert len(events) == 10
    assert tr.emitted == 25
    assert tr.dropped == 15
    # newest-wins: the ring holds exactly the last ten submissions
    assert [ev.data["req_id"] for ev in events] == list(range(15, 25))
    # aggregates are maintained OUTSIDE the ring: exact despite truncation
    assert tr.counters["submit"] == 25


def test_null_tracer_is_inert():
    calls = []
    assert NULL_TRACER.emit("finish", req_id=0) is None
    assert NULL_TRACER.span("iteration", 0.0) is None
    out = NULL_TRACER.timed_call(("k",), lambda x: calls.append(x) or x, 7)
    assert out == 7 and calls == [7]      # bare dispatch, no block/record
    assert NULL_TRACER.program_table() == {}
    assert not NULL_TRACER.enabled
    assert list(NULL_TRACER.events) == []


# ------------------------------------------------- traced engine: vocabulary

def test_traced_run_vocabulary_and_iteration_order(small_model):
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr)
    _submit_all(eng)
    eng.run(max_iterations=60)
    events = list(tr.events)
    assert events, "traced run emitted nothing"
    assert {ev.kind for ev in events} <= EVENT_KINDS
    iters = [ev.iteration for ev in events]
    assert iters == sorted(iters), "iteration stamps must be non-decreasing"
    # one scheduler decision and one iteration span per engine step
    assert tr.counters["scheduler"] == eng.iteration
    assert tr.counters["iteration"] == eng.iteration
    assert tr.counters["tokens"] == sum(s.new_tokens for s in eng.stats)
    assert tr.counters["finish:length"] == 3


def test_event_kinds_mirror_enforced_statically():
    """tools/trace_report.py is stdlib-only so it keeps its OWN copy of the
    vocabulary.  Enforcement lives in papilint's PL005 mirror checker,
    which parses both literal sets out of the source (and verifies every
    configured exporter mentions every kind) — so a drifted copy fails the
    lint gate before any test imports run.  One equality stays below as
    the runtime smoke assert."""
    root = Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root))
    from tools.papilint import checkers, load_config
    cfg = load_config(root / "pyproject.toml")
    assert checkers.check_mirrors(cfg, root) == []
    assert checkers.check_exporters(cfg, root) == []
    assert trace_report.EVENT_KINDS == EVENT_KINDS  # runtime smoke


def test_all_exporters_cover_every_event_kind(tmp_path):
    """Emit one event of every vocabulary kind, then check each of the
    three exporters surfaces all of them: the chrome trace round-trips
    every kind through load_trace, the jsonl export carries one record
    per kind, and the prometheus exposition zero-fills a
    papi_engine_events_total sample for the full vocabulary."""
    tr = Tracer()
    emitters = {
        "submit": dict(req_id=0, prompt_len=3, max_new=4),
        "admit": dict(req_id=0, slot=0, prompt_len=3),
        "first_token": dict(req_id=0),
        "preempt": dict(req_id=1, slot=1, done=2),
        "finish": dict(req_id=0, reason="length", tokens=4, slot=0),
        "defer": dict(req_id=2, age=3),
        "scheduler": dict(ai_estimate=1.0, alpha=6.0, assignment="pim",
                          flipped=True, rlp=1, tlp=2),
        "iteration": dict(new_tokens=1, fc_variant="pu"),
        "pool": dict(used=1, free=7, watermark=2, fragmentation=0.0),
        "fault": dict(fault="logits_nan"),
        "degraded": dict(mode="step"),
        "program": dict(key="decode|spec_len=1"),
        "page_map": dict(slot=0, pages=2),
        "page_unmap": dict(slot=0, pages=2, cause="finish"),
        "page_reserve": dict(slot=0, budget_pages=4, mapped_pages=2),
        "stall": dict(snapshot={"iteration": 5}),
        "journal": dict(op="open", path="wal.j", records=0,
                        truncated_bytes=0),
        "recover": dict(path="wal.j", resumed=2, finished=1, records=9,
                        torn_bytes=0, next_req_id=3),
    }
    assert set(emitters) == set(EVENT_KINDS), \
        "extend this test when the vocabulary grows"
    for kind, data in emitters.items():
        tr.emit(kind, iteration=1, **data)

    path = tmp_path / "t.trace.json"
    write_trace(tr, path, "chrome")
    events, _summary = trace_report.load_trace(path)
    assert {ev["kind"] for ev in events} == set(EVENT_KINDS)

    jsonl_kinds = {json.loads(line)["kind"]
                   for line in export_jsonl(tr).strip().splitlines()}
    assert jsonl_kinds == set(EVENT_KINDS) | {"summary"}

    samples = dict(re.findall(
        r'papi_engine_events_total\{kind="([^"]+)"\} (\d+)',
        export_prometheus(tr)))
    assert set(samples) == set(EVENT_KINDS)
    assert all(int(v) == 1 for v in samples.values())
    # zero-filled even on an empty tracer: the exposition always shows
    # the full vocabulary
    empty = dict(re.findall(
        r'papi_engine_events_total\{kind="([^"]+)"\} (\d+)',
        export_prometheus(Tracer())))
    assert set(empty) == set(EVENT_KINDS)
    assert all(int(v) == 0 for v in empty.values())


# ---------------------------------------------------------------- exporters

def test_chrome_export_round_trip(small_model, tmp_path):
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr)
    _submit_all(eng)
    eng.run(max_iterations=60)
    path = tmp_path / "t.trace.json"
    write_trace(tr, path, "chrome")
    doc = json.loads(path.read_text())
    assert "traceEvents" in doc and doc["traceEvents"]
    for rec in doc["traceEvents"]:
        assert rec["ph"] in ("M", "X", "i", "C")
        assert rec["pid"] == 1
        assert isinstance(rec["ts"], (int, float)) and rec["ts"] >= 0
        if rec["ph"] == "X":
            assert rec["dur"] >= 0
        if rec["ph"] == "C":       # Perfetto counter tracks: numeric-only
            assert all(isinstance(v, (int, float))
                       for v in rec["args"].values())
    papi = doc["papi"]
    assert papi["counters"]["iteration"] == eng.iteration
    assert papi["events_dropped"] == 0
    assert papi["programs"], "traced run must record program timings"
    # every admitted request got a residency span on a slot lane
    slot_spans = [r for r in doc["traceEvents"]
                  if r["ph"] == "X" and r.get("name", "").startswith("req ")]
    assert len(slot_spans) == 3


def test_prometheus_export_parses(small_model):
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr)
    _submit_all(eng)
    eng.run(max_iterations=60)
    text = export_prometheus(tr)
    line_re = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? -?[0-9eE.+-]+$')
    names = set()
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        assert line_re.match(line), f"unparseable sample line: {line!r}"
        names.add(line.split("{")[0].split(" ")[0])
    for required in ("papi_engine_iterations_total",
                     "papi_engine_tokens_total",
                     "papi_engine_preemptions_total",
                     "papi_engine_degraded_steps_total",
                     "papi_engine_kv_pages_used",
                     "papi_engine_program_runs_total"):
        assert required in names
    # values come from the aggregates, not the ring
    assert (f"papi_engine_iterations_total {eng.iteration}"
            in text.splitlines())


def test_jsonl_export_has_trailing_summary(small_model):
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr)
    _submit_all(eng, n=1)
    eng.run(max_iterations=30)
    lines = export_jsonl(tr).strip().splitlines()
    recs = [json.loads(ln) for ln in lines]
    assert all(r["kind"] in EVENT_KINDS for r in recs[:-1])
    assert recs[-1]["kind"] == "summary"
    assert recs[-1]["data"]["counters"]["iteration"] == eng.iteration
    assert recs[-1]["data"]["programs"]


# ----------------------------------------------------------- program timing

def test_program_table_hand_counted(small_model):
    """One greedy request, max_new=5, eos never fires: exactly 1 main
    prefill dispatch and 4 plain_fused decode dispatches (prefill commits
    token 1; each later iteration commits one)."""
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr)
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=5))
    res = eng.run(max_iterations=30)
    assert len(res[0].tokens) == 5
    table = tr.program_table()
    by_kind = {}
    for key, t in table.items():
        by_kind[key.split("|")[0]] = by_kind.get(key.split("|")[0], 0) \
            + t["count"]
    assert by_kind.get("main") == 1
    assert by_kind.get("plain_fused") == 4
    for t in table.values():
        assert t["count"] >= 1
        assert 0.0 <= t["min_s"] <= t["mean_s"] <= t["max_s"]
        assert abs(t["mean_s"] * t["count"] - t["total_s"]) < 1e-9
    # program events carry the formatted key and a nonzero duration
    progs = [ev for ev in tr.events if ev.kind == "program"]
    assert len(progs) == sum(t["count"] for t in table.values())
    assert all(ev.dur > 0 for ev in progs)


def test_format_program_key_compresses_defaults():
    assert format_program_key(("spec_fused", 4, "pim", None, False)) == \
        "spec_fused|4|pim|-|-"
    assert format_program_key(("main", "pu", True, True)) == "main|pu|True|True"


# ------------------------------------------------- observation only (serve)

def test_serve_streams_bit_identical_traced_vs_untraced(small_model):
    cfg, params = small_model
    schedule = [[ServeRequest(0, [3, 5, 7], max_new_tokens=6)], [],
                [ServeRequest(1, [4, 6], max_new_tokens=5)], [],
                [ServeRequest(2, [5, 7, 9, 11], max_new_tokens=4)]]

    def streams(tracer):
        eng = _engine(cfg, params, tracer=tracer)
        got = {}
        for ev in eng.serve([list(w) for w in schedule]):
            if ev.finished:
                got[ev.req_id] = ev.result.tokens
        return got

    untraced = streams(None)
    tr = Tracer()
    traced = streams(tr)
    assert traced == untraced
    assert tr.counters["finish:length"] == 3
    assert tr.counters["submit"] == 3


# ------------------------------------- scheduler, faults, degraded, stalls

def test_scheduler_events_carry_estimate_and_threshold(small_model,
                                                       draft_model):
    cfg, params = small_model
    dcfg, dparams = draft_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr, spec_len=3, draft=(dcfg, dparams))
    _submit_all(eng, n=4, max_new=8)
    eng.run(max_iterations=80)
    sched = [ev for ev in tr.events if ev.kind == "scheduler"]
    assert sched
    for ev in sched:
        assert ev.data["alpha"] == eng.scheduler.alpha
        assert ev.data["assignment"] in ("pu", "pim")
        assert isinstance(ev.data["ai_estimate"], float)
    flips = [ev for ev in sched if ev.data["flipped"]]
    assert len(flips) == tr.counters["scheduler_flip"]
    assert len(flips) <= eng.scheduler.num_reschedules
    # a spec run exercises >= 2 distinct compiled programs (draft + verify
    # at minimum; pu/pim variants when the scheduler flips)
    assert len(tr.program_table()) >= 2


def test_faults_and_degraded_events_match_engine_counts(small_model,
                                                        draft_model):
    cfg, params = small_model
    dcfg, dparams = draft_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr, spec_len=3, draft=(dcfg, dparams),
                  kv_layout="paged", page_size=8,
                  faults=FaultInjector(seed=3, nan_p=0.4, start=1, stop=8))
    _submit_all(eng, n=3, max_new=8)
    eng.run(max_iterations=80)
    assert eng.degraded_steps > 0, "fault seed never fired; test is vacuous"
    assert tr.counters["degraded"] == eng.degraded_steps
    assert tr.counters["fault:nan"] == eng.faults.counts["nan"]
    degraded_iters = {ev.iteration for ev in tr.events
                      if ev.kind == "degraded"}
    # trace events stamp the 0-based step index; IterStats.iteration is
    # recorded post-increment (1-based) — same steps, shifted by one
    flagged = {s.iteration - 1 for s in eng.stats if s.degraded}
    assert degraded_iters == flagged
    # the iteration spans carry the degraded flag too
    spans = {ev.iteration: ev.data["degraded"] for ev in tr.events
             if ev.kind == "iteration"}
    assert all(spans[i] for i in flagged)


def test_stall_event_emitted_before_raise(small_model):
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr, cache_capacity=16,
                  kv_layout="paged", page_size=4, stall_limit=5)
    eng.kv.can_admit = lambda *_: False
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=4))
    with pytest.raises(EngineStallError):
        eng.run(max_iterations=100)
    assert tr.counters["stall"] == 1
    stall = [ev for ev in tr.events if ev.kind == "stall"][-1]
    assert stall.data["snapshot"]["queue"] == [0]
    # deferral events accumulated while the head starved
    assert tr.counters["defer"] >= 5


def test_page_events_balance_on_drained_pool(small_model):
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr, cache_capacity=32,
                  kv_layout="paged", page_size=4)
    _submit_all(eng, n=3, max_new=6)
    eng.run(max_iterations=60)
    assert eng.kv.alloc.mapped_count == 0, "pool must drain after run()"
    mapped = sum(ev.data["mapped_pages"] for ev in tr.events
                 if ev.kind == "page_reserve")
    mapped += sum(ev.data["pages"] for ev in tr.events
                  if ev.kind == "page_map")
    unmapped = sum(ev.data["pages"] for ev in tr.events
                   if ev.kind == "page_unmap")
    assert mapped > 0
    assert mapped == unmapped
    # occupancy samples never exceed the watermark
    for ev in tr.events:
        if ev.kind == "pool":
            assert ev.data["used"] <= ev.data["watermark"]


# ----------------------------------------------------------- trace_report

def test_trace_report_validates_both_formats(small_model, tmp_path):
    cfg, params = small_model
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr)
    _submit_all(eng, n=2)
    eng.run(max_iterations=40)
    chrome = tmp_path / "t.trace.json"
    jsonl = tmp_path / "t.jsonl"
    write_trace(tr, chrome, "chrome")
    write_trace(tr, jsonl, "jsonl")
    assert trace_report.main([str(chrome), "--validate"]) == 0
    assert trace_report.main([str(jsonl), "--validate"]) == 0
    # the report (non-validate) path renders without error on both
    assert trace_report.main([str(chrome)]) == 0
    assert trace_report.main([str(jsonl)]) == 0
    # loader normalization: both serializations agree on the aggregates
    _, summ_c = trace_report.load_trace(chrome)
    _, summ_j = trace_report.load_trace(jsonl)
    assert summ_c["counters"] == summ_j["counters"]
    assert summ_c["programs"].keys() == summ_j["programs"].keys()


def test_trace_report_rejects_bad_traces(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"kind": "martian", "iteration": 0,
                               "ts": 0.0, "dur": 0.0, "data": {}}) + "\n")
    assert trace_report.main([str(bad), "--validate"]) == 1
    missing = tmp_path / "nope.json"
    assert trace_report.main([str(missing), "--validate"]) == 1
    # an empty trace fails the liveness gate (no scheduler/iteration events)
    empty = tmp_path / "empty.jsonl"
    tr = Tracer()
    write_trace(tr, empty, "jsonl")
    assert trace_report.main([str(empty), "--validate"]) == 1


# ----------------------------------------------------------------- metrics

def test_latency_summary_counts_and_single_token_tpot(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=1))
    eng.submit(ServeRequest(1, [4, 6], max_new_tokens=5))
    res = {r.req_id: r for r in eng.run(max_iterations=30)}
    assert len(res[0].tokens) == 1
    assert res[0].tpot_s is None, "tpot is undefined for a 1-token request"
    assert res[1].tpot_s is not None and res[1].tpot_s >= 0.0
    summ = latency_summary(res.values())
    assert summ["n"] == 2
    assert summ["ttft_s"]["count"] == 2
    assert summ["tpot_s"]["count"] == 1   # only the >= 2-token request
    for field, table in summ.items():
        if field == "n":
            continue
        assert set(table) >= {"p50", "p99", "mean", "count"}
