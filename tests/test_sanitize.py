"""Runtime sanitizer (src/repro/debug/sanitize.py):

  * `sanitized()` raises on implicit rank promotion (the model's own
    broadcasts are all explicit, so the strict mode stays on for whole
    engine runs);
  * a `PapiEngine(sanitize=True)` run completes with a report showing
    steady-state iterations at EXACTLY the transfer budget and zero
    steady-state recompiles, for both the plain and speculative fused
    engines — and `sanitize_report()` is None when the gate is off;
  * `EngineSanitizer.after_step` raises SanitizeError on a steady fused
    decode iteration whose transfer count exceeds the budget, and on a
    jit-cache entry that grew a second compiled signature under an
    existing key (a steady-state retrace);
  * non-steady iterations (admission waves, prefill chunks, degraded or
    preempted steps) are exempt from the budget.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.debug import EngineSanitizer, SanitizeError, sanitized
from repro.models import init_params
from repro.serving import PapiEngine, ServeRequest
from repro.serving.engine import IterStats


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, **kw):
    eng = PapiEngine(cfg, params, max_slots=2, cache_capacity=64,
                     prefill_len=8, alpha=6.0, eos_token=cfg.vocab_size - 1,
                     fused=True, sanitize=True, **kw)
    for i in range(3):
        eng.submit(ServeRequest(i, [3 + i, 5, 7], max_new_tokens=8))
    results = eng.run(max_iterations=100)
    return eng, results


def test_sanitized_raises_on_rank_promotion():
    with sanitized():
        with pytest.raises(Exception):  # jax raises ValueError/TypeError
            _ = jnp.ones((4, 8)) + jnp.ones((8,)) * jnp.ones((1, 1, 8))
    # and the strict context does not leak
    _ = jnp.ones((4, 1, 8)) + jnp.ones((8,))


def test_sanitized_engine_run_meets_budget(small_model):
    cfg, params = small_model
    eng, results = _run(cfg, params)
    assert len(results) == 3
    rep = eng.sanitize_report()
    assert rep is not None
    assert rep.steady_iterations > 0
    assert rep.transfers_per_steady_iter == rep.transfer_budget == 1
    assert rep.recompiles == 0
    assert rep.programs >= 1


def test_sanitized_speculative_run_meets_budget(small_model):
    cfg, params = small_model
    draft_params = init_params(cfg, jax.random.PRNGKey(9))
    eng, results = _run(cfg, params, spec_len=3, draft=(cfg, draft_params))
    assert len(results) == 3
    rep = eng.sanitize_report()
    assert rep.steady_iterations > 0
    assert rep.transfers_per_steady_iter == 1.0
    assert rep.recompiles == 0


def test_report_absent_when_gate_off(small_model):
    cfg, params = small_model
    eng = PapiEngine(cfg, params, max_slots=2, cache_capacity=64,
                     prefill_len=8, alpha=6.0, fused=True)
    assert eng.sanitize_report() is None


# ----------------------------------------------- after_step unit checks

def _stats(transfers, **kw):
    base = dict(iteration=5, rlp=1, tlp=1, ai_estimate=1.0,
                fc_variant="pu", new_tokens=1, accepted=1.0, wall_s=0.01,
                transfers=transfers, decode_slots=1)
    base.update(kw)
    return IterStats(**base)


class _FakeEngine:
    fused = True

    def __init__(self, stats, caches=None):
        self.stats = stats
        self._decode_jit = caches or {}
        self._prefill_jit = {}


class _FakeJit:
    def __init__(self, size):
        self._size = size

    def _cache_size(self):
        return self._size


def test_after_step_flags_budget_overrun():
    san = EngineSanitizer()
    with pytest.raises(SanitizeError, match="transfer budget"):
        san.after_step(_FakeEngine([_stats(transfers=2)]), stepped=True)


def test_after_step_exempts_non_steady_iterations():
    san = EngineSanitizer()
    # admission waves, prefill chunks, degrades, preemptions: over-budget
    # transfer counts are all legitimate off the steady state
    for extra in ({"admitted": 1}, {"arrivals": 1}, {"prefill_slots": 1},
                  {"degraded": 1}, {"preemptions": 1}):
        san.after_step(_FakeEngine([_stats(transfers=3, **extra)]),
                       stepped=True)
    assert san.report.steady_iterations == 0
    assert san.report.iterations == 5


def test_after_step_flags_steady_state_retrace():
    san = EngineSanitizer()
    eng = _FakeEngine([_stats(transfers=1)],
                      caches={("decode",): _FakeJit(1)})
    san.after_step(eng, stepped=True)
    eng._decode_jit[("decode",)] = _FakeJit(2)  # same key, new signature
    with pytest.raises(SanitizeError, match="retrace"):
        san.after_step(eng, stepped=True)


def test_after_step_counts_programs_across_caches():
    san = EngineSanitizer()
    eng = _FakeEngine([_stats(transfers=1)],
                      caches={("a",): _FakeJit(1), ("b",): _FakeJit(1)})
    san.after_step(eng, stepped=True)
    assert san.report.programs == 2
    assert san.report.steady_iterations == 1
    assert san.report.steady_transfers == 1


def test_report_asdict_round_trip():
    san = EngineSanitizer()
    san.after_step(_FakeEngine([_stats(transfers=1)]), stepped=True)
    d = san.report.asdict()
    assert d["transfers_per_steady_iter"] == 1.0
    assert set(d) >= {"transfer_budget", "iterations", "steady_iterations",
                      "steady_transfers", "recompiles", "programs"}
    assert dataclasses.asdict(san.report)["steady_iterations"] == 1
