"""Durability: write-ahead journal, snapshot/restore, crash recovery.

The contract under test (docs/ARCHITECTURE.md, "Durability & crash
recovery"):

  * the journal's framed records survive round-trips, and the reader
    discards exactly the torn tail — the first truncated / corrupt
    record and everything after it;
  * `Journal` reopened on an existing path physically truncates the torn
    tail, so a recovered engine appends to the SAME file and replay of
    the extended journal equals the uninterrupted history;
  * crash at iteration k (the deterministic ``crash`` fault, NO cleanup)
    -> `recover()` the durable finishes -> fresh engine `restore()` ->
    the union of durable + post-crash streams covers every journaled
    request EXACTLY ONCE, bit-identical to the uninterrupted oracle —
    for greedy and speculative, dense and paged KV (property-tested over
    crash point and torn-tail length via tests/_propcompat.py);
  * `replay` synthesizes a finish for a request whose committed prefix
    already exhausted its budget or hit eos (its finish record was torn
    away AFTER the result was externalized) — never re-runs it;
  * remaining deadlines survive the restart as monotonic deltas: a
    nearly-expired request times out shortly after recovery, a fresh one
    does not;
  * closing the `serve()` generator early aborts in-flight requests
    honestly, drains the pool, and leaves the engine reusable — but a
    crash PROPAGATING out of `serve()` runs no cleanup and journals no
    finalization, so the in-flight requests recover via restore().
"""
import json
import tempfile
from pathlib import Path

import jax
import pytest
from _propcompat import given, settings, st

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (EngineCrashError, FaultInjector, Journal,
                           PapiEngine, ServeRequest, parse_fault_specs,
                           read_records, recover, replay)
from repro.serving.journal import FLUSH_POLICIES, scan

NO_EOS = get_config("qwen2-0.5b").reduced().vocab_size - 1

# four requests of staggered length: some finish before any crash point,
# some after, so every recovery splits durable-vs-resumed nontrivially
REQS = [([3 + i, 5, 7], 6 + 2 * i) for i in range(4)]

# module-level model cache: the _propcompat fallback runner can't mix
# pytest fixtures with @given, and the property test shares the oracle
_CACHE: dict = {}


def _model():
    if "model" not in _CACHE:
        cfg = get_config("qwen2-0.5b").reduced()
        _CACHE["model"] = (cfg, init_params(cfg, jax.random.PRNGKey(0)),
                           init_params(cfg, jax.random.PRNGKey(9)))
    return _CACHE["model"]


def _engine(layout="dense", spec=1, **kw):
    cfg, params, draft_params = _model()
    d = dict(max_slots=4, cache_capacity=64, prefill_len=8, alpha=6.0,
             eos_token=NO_EOS, debug_invariants=True)
    if spec > 1:
        d.update(spec_len=spec, draft=(cfg, draft_params))
    if layout == "paged":
        d.update(kv_layout="paged", page_size=4)
    d.update(kw)
    return PapiEngine(cfg, params, **d)


def _submit_all(eng):
    for i, (prompt, n) in enumerate(REQS):
        eng.submit(ServeRequest(i, list(prompt), max_new_tokens=n))


def _oracle(layout, spec):
    key = ("oracle", layout, spec)
    if key not in _CACHE:
        eng = _engine(layout, spec)
        _submit_all(eng)
        _CACHE[key] = {r.req_id: r.tokens
                       for r in eng.run(max_iterations=400)}
    return _CACHE[key]


# ------------------------------------------------------------ journal file

def test_framing_roundtrip(tmp_path):
    path = tmp_path / "a.wal"
    with Journal(path) as j:
        j.append("submit", req_id=0, prompt=[1, 2, 3], max_new=8, dl=None)
        j.append("commit", req_id=0, toks=[5, 6], n=2, rem=6, dl=None, it=1)
        j.append("finish", req_id=0, reason="length", toks=[7], n=3, it=2)
    records, torn = read_records(path)
    assert torn == 0
    assert [r["k"] for r in records] == ["submit", "commit", "finish"]
    assert records[0]["prompt"] == [1, 2, 3]
    assert records[2]["toks"] == [7]
    with pytest.raises(AssertionError):
        Journal(tmp_path / "b.wal").append("not-a-kind", req_id=0)


def test_torn_tail_stops_reader_and_reopen_truncates(tmp_path):
    path = tmp_path / "torn.wal"
    with Journal(path) as j:
        for i in range(5):
            j.append("commit", req_id=0, toks=[i], n=i + 1, rem=5 - i,
                     dl=None, it=i)
    whole = path.read_bytes()
    cut = whole[:-9]                         # tear the last record
    path.write_bytes(cut)
    records, torn = read_records(path)
    assert len(records) == 4
    assert torn == len(cut) - (cut.rfind(b"\n") + 1) > 0
    # reopening physically truncates, so appends extend a valid prefix
    j2 = Journal(path)
    assert j2.records_kept == 4 and j2.truncated_bytes == torn
    j2.append("commit", req_id=0, toks=[9], n=5, rem=1, dl=None, it=9)
    j2.close()
    records, torn = read_records(path)
    assert torn == 0 and len(records) == 5 and records[-1]["toks"] == [9]


def test_checksum_corruption_stops_reader(tmp_path):
    path = tmp_path / "corrupt.wal"
    with Journal(path) as j:
        for i in range(4):
            j.append("preempt", req_id=i, done=i, it=i)
    data = bytearray(path.read_bytes())
    lines = bytes(data).split(b"\n")
    # flip one byte inside record 1's json body
    off = len(lines[0]) + 1 + lines[1].rfind(b"}")
    data[off - 2] ^= 0xFF
    records, valid_end, total = scan(bytes(data))
    assert len(records) == 1 and valid_end < total


def test_flush_policies(tmp_path):
    with pytest.raises(ValueError):
        Journal(tmp_path / "x.wal", flush="never")
    assert set(FLUSH_POLICIES) == {"fsync", "flush", "lazy"}
    lazy = Journal(tmp_path / "lazy.wal", flush="lazy")
    lazy.append("cancel", req_id=0, it=0)
    assert (tmp_path / "lazy.wal").stat().st_size == 0   # still buffered
    lazy.close()
    assert read_records(tmp_path / "lazy.wal")[0][0]["k"] == "cancel"
    sync = Journal(tmp_path / "sync.wal", flush="fsync")
    sync.append("cancel", req_id=1, it=0)
    assert read_records(tmp_path / "sync.wal")[0][0]["k"] == "cancel"
    sync.close()


# ------------------------------------------------------------------ replay

def test_replay_folds_and_orders():
    recs = [
        {"k": "submit", "req_id": 0, "prompt": [1, 2], "max_new": 9,
         "dl": None},
        {"k": "submit", "req_id": 1, "prompt": [3], "max_new": 4, "dl": 2.5},
        {"k": "admit", "req_id": 0, "slot": 0, "budget": 8, "it": 0},
        {"k": "commit", "req_id": 0, "toks": [7, 8], "n": 2, "rem": 6,
         "dl": None, "it": 1},
        {"k": "preempt", "req_id": 0, "done": 2, "it": 2},
    ]
    state = replay(recs)
    # preemption requeues at the back: recovery keeps that order
    assert state.req_ids == [1, 0]
    r0 = state.requests[1]
    assert r0.done == [7, 8] and r0.max_new == 6 and r0.prompt == [1, 2]
    assert state.requests[0].deadline_s == 2.5
    assert state.next_req_id == 2 and not state.finished


def test_replay_synthesizes_torn_finish():
    base = [{"k": "submit", "req_id": 0, "prompt": [1], "max_new": 3,
             "dl": None},
            {"k": "admit", "req_id": 0, "slot": 0, "budget": 3, "it": 0}]
    # budget exhausted by the last durable commit; finish record torn away
    state = replay(base + [{"k": "commit", "req_id": 0, "toks": [5, 6, 7],
                            "n": 3, "rem": 0, "dl": None, "it": 2}])
    assert not state.requests
    fin = state.finished[0]
    assert fin.synthesized and fin.reason == "length"
    assert fin.tokens == [5, 6, 7]
    # same for an eos tail with budget remaining
    state = replay(base + [{"k": "commit", "req_id": 0, "toks": [5, 99],
                            "n": 2, "rem": 1, "dl": None, "it": 1}],
                   eos_token=99)
    assert not state.requests
    assert state.finished[0].synthesized
    assert state.finished[0].reason == "eos"
    # without eos knowledge the request is (correctly) re-admitted
    state = replay(base + [{"k": "commit", "req_id": 0, "toks": [5, 99],
                            "n": 2, "rem": 1, "dl": None, "it": 1}])
    assert state.req_ids == [0]


# ------------------------------------------------------------- crash fault

def test_crash_fault_deterministic_and_windowed():
    a = FaultInjector(seed=7, crash_p=0.5)
    b = FaultInjector(seed=7, crash_p=0.5)
    seq = [a.crash_now(s) for s in range(64)]
    assert seq == [b.crash_now(s) for s in range(64)]
    assert any(seq) and not all(seq)
    assert a.counts["crash"] == sum(seq)
    w = FaultInjector(seed=7, crash_p=1.0, start=5, stop=6)
    assert [w.crash_now(s) for s in range(8)] == [False] * 5 + [True,
                                                                False, False]
    assert not FaultInjector(seed=7).crash_now(3)


def test_parse_fault_specs_crash():
    inj = parse_fault_specs(["crash:0.25"])
    assert inj.crash_p == 0.25 and inj.nan_p == 0.0
    inj = parse_fault_specs(["crash", "nan:0.1"])
    assert inj.crash_p == 1.0 and inj.nan_p == 0.1
    with pytest.raises(ValueError):
        parse_fault_specs(["crash:1.5"])
    with pytest.raises(ValueError):
        parse_fault_specs(["crash:x"])


# ------------------------------------------------- crash -> restore -> run

def _crash_and_recover(layout, spec, k, wal, truncate=0):
    """Crash at iteration k, optionally tear `truncate` bytes off the
    journal, then restore a FRESH engine and complete.  Returns
    (durable finishes, post-crash results, surviving submit ids)."""
    eng = _engine(layout, spec, journal=wal,
                  faults=FaultInjector(seed=0, crash_p=1.0,
                                       start=k, stop=k + 1))
    _submit_all(eng)
    with pytest.raises(EngineCrashError) as exc:
        eng.run(max_iterations=400)
    assert exc.value.iteration == k
    if truncate:
        data = Path(wal).read_bytes()
        Path(wal).write_bytes(data[:max(0, len(data) - truncate)])
    records, _ = read_records(wal)
    known = {int(r["req_id"]) for r in records if r["k"] == "submit"}
    durable = {rid: f.tokens
               for rid, f in recover(wal, eos_token=NO_EOS).finished.items()}
    fresh = _engine(layout, spec, journal=wal)
    fresh.restore(wal)
    after = {r.req_id: r.tokens for r in fresh.run(max_iterations=400)}
    return durable, after, known


@pytest.mark.parametrize("layout,spec", [("dense", 1), ("paged", 2)])
def test_crash_recovery_bit_identical(layout, spec, tmp_path):
    """Crash mid-trace -> recover -> the union of durable + post-crash
    streams is the oracle, exactly once — and replay of the SAME journal
    file (extended by the recovered engine) equals the full history."""
    oracle = _oracle(layout, spec)
    wal = str(tmp_path / "crash.wal")
    durable, after, known = _crash_and_recover(layout, spec, 3, wal)
    assert known == set(oracle)
    assert not set(durable) & set(after)          # exactly-once finishes
    union = {**durable, **after}
    assert union == oracle                        # bit-identical
    # the recovered engine appended to the same file: replaying the
    # extended journal reconstructs the uninterrupted history
    final = recover(wal, eos_token=NO_EOS)
    assert not final.requests
    assert {rid: f.tokens for rid, f in final.finished.items()} == oracle


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=160),
       st.sampled_from(["dense", "paged"]))
def test_crash_consistency_property(k, cut, layout):
    """Fuzz (crash iteration, torn-tail length, KV layout): every request
    whose submit record survived the tear completes exactly once with the
    oracle's stream — no duplicate finish, no lost committed token."""
    oracle = _oracle(layout, 1)
    with tempfile.TemporaryDirectory() as td:
        wal = str(Path(td) / "p.wal")
        durable, after, known = _crash_and_recover(layout, 1, k, wal,
                                                   truncate=cut)
    assert not set(durable) & set(after)
    union = {**durable, **after}
    assert set(union) == known
    for rid in known:
        assert union[rid] == oracle[rid], rid


# -------------------------------------------------------- snapshot/restore

def test_snapshot_restore_completes(tmp_path):
    oracle = _oracle("dense", 1)
    eng = _engine(faults=FaultInjector(seed=0, crash_p=1.0, start=3,
                                       stop=4))
    _submit_all(eng)
    with pytest.raises(EngineCrashError):
        eng.run(max_iterations=400)
    snap = tmp_path / "engine.snap.json"
    state = eng.snapshot(str(snap))
    assert state["papi_snapshot"] == 1
    pre = {r.req_id: r.tokens for r in eng.results}
    fresh = _engine()
    info = fresh.restore(str(snap))
    assert info["resumed"] == len(state["requests"])
    after = {r.req_id: r.tokens for r in fresh.run(max_iterations=400)}
    assert not set(pre) & set(after)
    assert {**pre, **after} == oracle


def test_deadline_survives_restart_both_directions(tmp_path):
    """Satellite: deadlines persist as REMAINING monotonic deltas.  After
    recovery on a machine whose clock jumped far ahead, the nearly-expired
    request still times out on its remaining budget (keeping its committed
    tokens) while the fresh request completes in full."""
    oracle = _oracle("dense", 1)
    eng = _engine(faults=FaultInjector(seed=0, crash_p=1.0, start=4,
                                       stop=5))
    clock = {"now": 100.0}
    eng._now = lambda: clock["now"]
    for i, (prompt, n) in enumerate(REQS):
        eng.submit(ServeRequest(i, list(prompt), max_new_tokens=n,
                                deadline_s=5.0 if i == 0 else 1000.0))
    with pytest.raises(EngineCrashError):
        eng.run(max_iterations=400)
    clock["now"] = 104.8          # request 0 has 0.2s of deadline left
    snap = tmp_path / "dl.snap.json"
    eng.snapshot(str(snap))
    by_id = {r["req_id"]: r for r in
             json.loads(snap.read_text())["requests"]}
    assert by_id[0]["deadline_s"] == pytest.approx(0.2)
    assert by_id[3]["deadline_s"] == pytest.approx(995.2)

    fresh = _engine()
    c2 = {"now": 1e6}             # wall clock far-jumped across the restart
    fresh._now = lambda: c2["now"]
    fresh.restore(str(snap))
    done0 = {r.req_id: list(r.done) for r in fresh.queue}[0]
    c2["now"] = 1e6 + 0.5         # past 0's remaining 0.2s, inside 3's
    got = {r.req_id: r for r in fresh.run(max_iterations=400)}
    assert got[0].finished_reason == "timeout"
    # committed tokens kept, stream still an oracle prefix, cut short
    assert len(done0) <= len(got[0].tokens) < len(oracle[0])
    assert got[0].tokens == oracle[0][:len(got[0].tokens)]
    for rid in (1, 2, 3):
        if rid in got:            # finished pre-crash otherwise
            assert got[rid].finished_reason == "length"
            assert got[rid].tokens == oracle[rid]


# ----------------------------------------------------- serve() early close

def test_serve_early_close_aborts_and_stays_usable():
    """Satellite: breaking out of the serve() generator mid-stream aborts
    in-flight requests honestly, drains the page pool, and the engine
    remains usable for a subsequent submit() + run()."""
    eng = _engine("paged")
    sched = [[ServeRequest(i, list(p), max_new_tokens=n)
              for i, (p, n) in enumerate(REQS)]]
    for ev in eng.serve(sched):
        break                     # close the generator after one event
    assert not eng.active_slots
    aborted = [r for r in eng.results if r.finished_reason == "aborted"]
    assert aborted                # in-flight requests were finished
    eng.kv.alloc.check()
    assert eng.kv.alloc.mapped_count == 0
    assert eng.kv.alloc.free_count == eng.kv.alloc.num_pages
    # the engine is reusable: queued requests + a new one complete offline
    eng.submit(ServeRequest(99, [11, 13], max_new_tokens=4))
    later = {r.req_id: r for r in eng.run(max_iterations=400)}
    assert later[99].finished_reason == "length"
    assert len(later[99].tokens) == 4


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_serve_crash_is_recoverable_not_aborted(layout, tmp_path):
    """Regression: `EngineCrashError` escaping the serve() generator is a
    simulated process death, NOT an early close — it must skip the finally
    abort cleanup entirely.  Journaling "aborted" finishes there would
    durably mark the in-flight requests done, so --resume would skip them
    and their remaining tokens would be silently lost."""
    oracle = _oracle(layout, 1)
    wal = str(tmp_path / "serve-crash.wal")
    eng = _engine(layout, journal=wal,
                  faults=FaultInjector(seed=0, crash_p=1.0, start=3,
                                       stop=4))
    sched = [[ServeRequest(i, list(p), max_new_tokens=n)
              for i, (p, n) in enumerate(REQS)]]
    streamed: dict[int, list[int]] = {}
    with pytest.raises(EngineCrashError) as exc:
        for ev in eng.serve(sched):
            if not ev.finished:
                streamed.setdefault(ev.req_id, []).append(ev.token)
    assert exc.value.iteration == 3
    # no cleanup ran: slots are still live and nothing was finalized in
    # the journal as "aborted" (or at all, for the in-flight requests)
    assert eng.active_slots
    records, _ = read_records(wal)
    assert not any(r["k"] == "finish" and r["reason"] == "aborted"
                   for r in records)
    # recovery re-admits the in-flight requests and completes them
    # bit-identically to the oracle, finishes exactly-once
    durable = {rid: f.tokens
               for rid, f in recover(wal, eos_token=NO_EOS).finished.items()}
    fresh = _engine(layout, journal=wal)
    fresh.restore(wal)
    after = {r.req_id: r.tokens for r in fresh.run(max_iterations=400)}
    assert not set(durable) & set(after)
    assert {**durable, **after} == oracle
    # every token streamed before the crash was an oracle prefix
    for rid, toks in streamed.items():
        assert toks == oracle[rid][:len(toks)], rid
