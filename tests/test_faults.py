"""Direct unit coverage for serving/faults.py (previously only exercised
indirectly through engine tests): FaultInjector determinism and the
parse_fault_specs validation surface."""
import pytest

from repro.serving.faults import (FAULT_INF, FAULT_NAN, FAULT_NONE,
                                  FaultInjector, parse_fault_specs)


def test_injector_pure_function_of_seed_and_step():
    """Same (seed, iteration) -> same decision, every consult, across
    injector instances and repeated calls (a re-consulted step replays)."""
    a = FaultInjector(seed=42, admit_p=0.5, nan_p=0.3, kernel_p=0.3,
                      latency_p=0.5)
    b = FaultInjector(seed=42, admit_p=0.5, nan_p=0.3, kernel_p=0.3,
                      latency_p=0.5)
    for step in range(200):
        assert a.admission_blocked(step) == b.admission_blocked(step)
        assert a.logits_fault(step) == b.logits_fault(step)
        assert a.step_delay(step) == b.step_delay(step)
        # repeated consult of the same step replays identically
        assert a.logits_fault(step) == b.logits_fault(step)
    assert a.counts == b.counts
    # decisions actually vary over steps (the schedule isn't constant)
    hits = [FaultInjector(seed=42, nan_p=0.3).logits_fault(s) == FAULT_NAN
            for s in range(100)]
    assert any(hits) and not all(hits)


def test_injector_different_seeds_differ():
    sched = [FaultInjector(seed=s, nan_p=0.5).logits_fault(i)
             for s in (0, 1) for i in range(50)]
    assert sched[:50] != sched[50:]


def test_injector_window_respected():
    inj = FaultInjector(seed=7, admit_p=1.0, nan_p=1.0, latency_p=1.0,
                        start=10, stop=20)
    for step in range(30):
        inside = 10 <= step < 20
        assert inj.admission_blocked(step) == inside
        assert (inj.logits_fault(step) != FAULT_NONE) == inside
        assert (inj.step_delay(step) > 0) == inside
    assert inj.counts["admit"] == inj.counts["nan"] == 10


def test_nan_wins_over_kernel():
    inj = FaultInjector(seed=0, nan_p=1.0, kernel_p=1.0)
    assert inj.logits_fault(3) == FAULT_NAN
    only_kernel = FaultInjector(seed=0, kernel_p=1.0)
    assert only_kernel.logits_fault(3) == FAULT_INF


def test_parse_specs_builds_injector():
    inj = parse_fault_specs(["nan:0.2", "admit"], seed=5, latency_s=0.01)
    assert inj.seed == 5
    assert inj.nan_p == pytest.approx(0.2)
    assert inj.admit_p == 1.0
    assert inj.kernel_p == inj.latency_p == 0.0
    assert parse_fault_specs([]) is None


def test_parse_specs_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_fault_specs(["gamma-ray"])


@pytest.mark.parametrize("spec", ["nan:1.5", "admit:-0.1", "kernel:2"])
def test_parse_specs_rejects_out_of_range_probability(spec):
    with pytest.raises(ValueError, match=r"outside \[0, 1\]"):
        parse_fault_specs([spec])


def test_parse_specs_rejects_non_numeric_probability():
    with pytest.raises(ValueError, match="not a number"):
        parse_fault_specs(["nan:often"])
