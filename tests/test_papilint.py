"""papilint static-analysis suite (tools/papilint):

  * PL001 flags host syncs (.item(), device_get, int()-on-device, the
    sanctioned `_fetch` wrapper) reachable from the configured hot-path
    closure, and an allow-transfer annotation WITH a reason silences it
    (a reasonless or unrecognized annotation is PL000);
  * PL002 flags getters returning non-(key, fn) shapes, bare dispatch of
    getter-returned programs, key/fn mismatches through `_call`, and
    direct calls into a `*_jit` cache;
  * PL003 reproduces the seed's jit-cache-key bug as a fixture — a key
    blind to the ambient FC variant — plus a read-but-not-keyed flag;
    keys derived from `_jit_key` or capturing the ambient reads pass,
    and a disable annotation with a reason is honored;
  * PL004 flags index_map arity mismatches against grid rank + scalar
    prefetch, kernel positional-ref counts against the spec totals, and
    clamped (ragged-tail) index maps whose kernel has no pl.when guard;
  * PL005 flags mirror drift, exporters missing event kinds, and
    undocumented CLI flags;
  * the config parser round-trips the real [tool.papilint] table and
    rejects non-string values;
  * the repo itself lints clean: `python -m tools.papilint src tools
    benchmarks` exits 0 (the CI gate), and a bad fixture exits 1.
"""
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))

from tools.papilint.config import (Config, ConfigError,  # noqa: E402
                                   load_config, parse_pyproject)
from tools.papilint.core import run_paths  # noqa: E402


def lint(tmp_path, source, cfg, name="mod.py"):
    f = tmp_path / name
    f.write_text(textwrap.dedent(source))
    return run_paths([f], cfg, tmp_path)


def codes(violations):
    return [v.code for v in violations]


# ------------------------------------------------------------------ PL001

HOT_CFG = Config(hot_path=["mod.py::Engine.step"],
                 transfer_wrappers=["_fetch"],
                 host_state_attrs=["iteration"])


def test_pl001_item_in_hot_path(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def step(self):
                x = self._call(("k",), None)
                return x.item()
        """, HOT_CFG)
    assert codes(vs) == ["PL001"]
    assert ".item()" in vs[0].message


def test_pl001_transitive_closure_reaches_helpers(tmp_path):
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def step(self):
                return self._inner()

            def _inner(self):
                return jax.device_get(self.buf)
        """, HOT_CFG)
    assert codes(vs) == ["PL001"]
    assert "device_get" in vs[0].message


def test_pl001_annotated_sync_is_sanctioned(tmp_path):
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def step(self):
                # papilint: allow-transfer(the iteration's one fetch)
                return jax.device_get(self.buf)
        """, HOT_CFG)
    assert vs == []


def test_pl001_reasonless_annotation_is_pl000(tmp_path):
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def step(self):
                # papilint: allow-transfer()
                return jax.device_get(self.buf)
        """, HOT_CFG)
    assert set(codes(vs)) == {"PL000", "PL001"}


def test_pl001_unrecognized_annotation_is_pl000(tmp_path):
    vs = lint(tmp_path, """
        # papilint: frobnicate the widgets
        X = 1
        """, Config())
    assert codes(vs) == ["PL000"]


def test_pl001_int_on_device_value(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def step(self):
                x = self._call(("k",), None)
                return int(x)
        """, HOT_CFG)
    assert codes(vs) == ["PL001"]
    assert "int()" in vs[0].message


def test_pl001_host_state_arithmetic_is_clean(tmp_path):
    vs = lint(tmp_path, """
        import numpy as np

        class Engine:
            def step(self):
                n = int(self.iteration)
                h = self._fetch(self.buf)
                m = np.asarray(h)
                return n + int(h) + int(m[0])
        """, HOT_CFG)
    # only the un-annotated _fetch call itself should be flagged
    assert codes(vs) == ["PL001"]
    assert "_fetch" in vs[0].message


def test_pl001_cold_functions_are_ignored(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def step(self):
                return 1

            def debug_dump(self):
                return self.buf.item()
        """, HOT_CFG)
    assert vs == []


# ------------------------------------------------------------------ PL002

ENGINE_CFG = Config(engine_files=["mod.py"])


def test_pl002_bare_dispatch_flagged(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def _get_prog(self):
                key = ("k",)
                return key, self.fn

            def step(self):
                key, fn = self._get_prog()
                return fn(1)
        """, ENGINE_CFG)
    assert codes(vs) == ["PL002"]
    assert "bare dispatch" in vs[0].message


def test_pl002_getter_must_return_pair(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def _get_prog(self):
                return self.fn
        """, ENGINE_CFG)
    assert codes(vs) == ["PL002"]
    assert "(key, fn)" in vs[0].message


def test_pl002_key_fn_mismatch(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def _get_prog(self):
                key = ("k",)
                return key, self.fn

            def step(self):
                key, fn = self._get_prog()
                other = ("x",)
                return self._call(other, fn, 1)
        """, ENGINE_CFG)
    assert codes(vs) == ["PL002"]
    assert "misattributed" in vs[0].message


def test_pl002_direct_jit_cache_call(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def step(self):
                return self._decode_jit[("k",)](self.x)
        """, ENGINE_CFG)
    assert codes(vs) == ["PL002"]
    assert "_decode_jit" in vs[0].message


def test_pl002_routed_dispatch_is_clean(tmp_path):
    vs = lint(tmp_path, """
        class Engine:
            def _get_prog(self):
                key = ("k",)
                return key, self.fn

            def step(self):
                key, fn = self._get_prog()
                return self._call(key, fn, 1)
        """, ENGINE_CFG)
    assert vs == []


# ------------------------------------------------------------------ PL003

KEY_CFG = Config(engine_files=["mod.py"],
                 jit_key_flags=["spec_len"],
                 ambient_key_reads=["current_fc_variant",
                                    "current_fc_interpret"])


def test_pl003_seed_bug_regression(tmp_path):
    # the seed's actual bug: a (kind, spec_len) key that never captures
    # the ambient FC variant, so whichever variant traced first is baked
    # into the cache and a scheduler flip silently reuses it
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def _get_decode(self):
                key = ("decode", self.spec_len)
                fn = jax.jit(lambda x: x)
                return key, fn
        """, KEY_CFG)
    assert codes(vs) == ["PL003"]
    assert "seed bug" in vs[0].message


def test_pl003_flag_read_but_not_keyed(tmp_path):
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def _get_decode(self):
                key = ("decode", current_fc_variant())
                window = self.spec_len + 1
                fn = jax.jit(lambda x: x[:window])
                return key, fn
        """, KEY_CFG)
    assert codes(vs) == ["PL003"]
    assert "self.spec_len" in vs[0].message


def test_pl003_builder_derived_key_is_clean(tmp_path):
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def _jit_key(self, kind):
                return (kind, self.spec_len, self.scheduler.fc_assignment)

            def _get_decode(self):
                key = self._jit_key("decode")
                fn = jax.jit(lambda x: x)
                return key, fn
        """, KEY_CFG)
    assert vs == []


def test_pl003_ambient_capturing_key_is_clean(tmp_path):
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def _get_prefill(self):
                key = ("prefill", current_fc_variant(),
                       current_fc_interpret())
                fn = jax.jit(lambda x: x)
                return key, fn
        """, KEY_CFG)
    assert vs == []


def test_pl003_disable_annotation_honored(tmp_path):
    vs = lint(tmp_path, """
        import jax

        class Engine:
            def _get_oracle(self):
                # papilint: disable=PL003 (oracle pins the variant at dispatch)
                key = ("oracle",)
                fn = jax.jit(lambda x: x)
                return key, fn
        """, KEY_CFG)
    assert vs == []


# ------------------------------------------------------------------ PL004

def test_pl004_index_map_arity(tmp_path):
    vs = lint(tmp_path, """
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                _kernel,
                grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
        """, Config())
    assert codes(vs) == ["PL004"]
    assert "1 parameter(s)" in vs[0].message and "provides 2" in vs[0].message


def test_pl004_kernel_ref_count(tmp_path):
    vs = lint(tmp_path, """
        from jax.experimental import pallas as pl

        def _kernel(x_ref, y_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                _kernel,
                grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
        """, Config())
    assert codes(vs) == ["PL004"]
    assert "3 positional ref(s)" in vs[0].message


def test_pl004_clamp_without_when_guard(tmp_path):
    vs = lint(tmp_path, """
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            o_ref[...] = o_ref[...] + x_ref[...]

        def run(x):
            def x_index(i, j):
                return (jnp.minimum(i, 3), j)
            return pl.pallas_call(
                _kernel,
                grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), x_index)],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
        """, Config())
    assert codes(vs) == ["PL004"]
    assert "pl.when" in vs[0].message


def test_pl004_guarded_clamp_is_clean(tmp_path):
    vs = lint(tmp_path, """
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            @pl.when(pl.program_id(0) < 3)
            def _():
                o_ref[...] = o_ref[...] + x_ref[...]

        def run(x):
            def x_index(i, j):
                return (jnp.minimum(i, 3), j)
            return pl.pallas_call(
                _kernel,
                grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), x_index)],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
        """, Config())
    assert vs == []


# ------------------------------------------------------------------ PL005

def test_pl005_mirror_drift(tmp_path):
    (tmp_path / "a.py").write_text('KINDS = frozenset({"a", "b"})\n')
    (tmp_path / "b.py").write_text('KINDS = frozenset({"a"})\n')
    cfg = Config(mirrors=["a.py::KINDS=b.py::KINDS"])
    vs = run_paths([], cfg, tmp_path)
    assert codes(vs) == ["PL005"]
    assert "mirror drift" in vs[0].message and "'b'" in vs[0].message


def test_pl005_mirror_in_sync(tmp_path):
    (tmp_path / "a.py").write_text('KINDS = frozenset({"a", "b"})\n')
    (tmp_path / "b.py").write_text('KINDS = frozenset({"b", "a"})\n')
    cfg = Config(mirrors=["a.py::KINDS=b.py::KINDS"])
    assert run_paths([], cfg, tmp_path) == []


def test_pl005_exporter_missing_kind(tmp_path):
    (tmp_path / "a.py").write_text('KINDS = frozenset({"a", "b"})\n')
    (tmp_path / "exp.py").write_text(textwrap.dedent("""
        def export(tracer):
            return ["a"]
        """))
    cfg = Config(event_kinds_source="a.py::KINDS",
                 exporters=["exp.py::export"])
    vs = run_paths([], cfg, tmp_path)
    assert codes(vs) == ["PL005"]
    assert "'b'" in vs[0].message


def test_pl005_undocumented_cli_flag(tmp_path):
    (tmp_path / "cli.py").write_text(textwrap.dedent("""
        import argparse
        ap = argparse.ArgumentParser()
        ap.add_argument("--documented")
        ap.add_argument("--mystery-knob")
        """))
    (tmp_path / "doc.md").write_text("Use `--documented` to do things.\n")
    cfg = Config(cli_docs=["cli.py=doc.md"])
    vs = run_paths([], cfg, tmp_path)
    assert codes(vs) == ["PL005"]
    assert "--mystery-knob" in vs[0].message


# ------------------------------------------------------------------ config

def test_config_parses_real_pyproject():
    cfg = load_config(REPO_ROOT / "pyproject.toml")
    assert "src/repro/serving/engine.py" in cfg.engine_files
    assert "_fetch" in cfg.transfer_wrappers
    assert "spec_len" in cfg.jit_key_flags
    assert cfg.mirrors and cfg.exporters and cfg.cli_docs


def test_config_rejects_non_string_values():
    text = "[tool.papilint]\nhot_path = 3\n"
    with pytest.raises(ConfigError):
        parse_pyproject(text)


def test_config_multiline_arrays():
    text = textwrap.dedent("""
        [tool.papilint]
        hot_path = [
            "a.py::X.y",
            "b.py::Z.w",
        ]
        """)
    raw = parse_pyproject(text)
    assert raw["hot_path"] == ["a.py::X.y", "b.py::Z.w"]


# -------------------------------------------------------------- repo gate

def test_repo_lints_clean():
    """The CI gate: the repo's own src/tools/benchmarks trees carry no
    unannotated violations under the real [tool.papilint] config."""
    from tools.papilint.__main__ import main
    assert main(["src", "tools", "benchmarks"]) == 0


def test_bad_fixture_exits_nonzero(tmp_path):
    from tools.papilint.__main__ import main
    (tmp_path / "pyproject.toml").write_text(textwrap.dedent("""
        [tool.papilint]
        engine_files = ["mod.py"]
        """))
    (tmp_path / "mod.py").write_text(textwrap.dedent("""
        class Engine:
            def _get_prog(self):
                return self.fn
        """))
    assert main(["mod.py", "--root", str(tmp_path)]) == 1
