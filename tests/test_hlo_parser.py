"""Unit tests for the HLO text analyzer on synthetic-but-realistic IR."""
from repro.launch.hlo import HLOAnalysis

SYNTH = """
HloModule jit_fn, entry_computation_layout={...}

%region_body.1 (arg: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %arg = (s32[], f32[8,128]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%arg), index=1
  %w = f32[128,128]{1,0} constant({...})
  %dot.1 = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,256]{1,0} all-gather(%dot.1), replica_groups=[8,2]<=[16], dimensions={1}
  %one = s32[] constant(1)
  %next = s32[] add(%iv, %one)
  ROOT %out = (s32[], f32[8,128]) tuple(%next, %dot.1)
}

%region_cond.2 (arg.1: (s32[], f32[8,128])) -> pred[] {
  %arg.1 = (s32[], f32[8,128]) parameter(0)
  %iv.1 = s32[] get-tuple-element(%arg.1), index=0
  %bound = s32[] constant(24)
  ROOT %cmp = pred[] compare(%iv.1, %bound), direction=LT
}

ENTRY %main.3 (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %p0)
  %loop = (s32[], f32[8,128]) while(%init), condition=%region_cond.2, body=%region_body.1
  %res = f32[8,128]{1,0} get-tuple-element(%loop), index=1
  %ar = f32[8,128]{1,0} all-reduce(%res), replica_groups={{0,1,2,3}}, to_apply=%add.red
  ROOT %copy = f32[8,128]{1,0} copy(%ar)
}

%add.red (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""


def test_dot_flops_with_trip_count():
    h = HLOAnalysis(SYNTH, num_devices=16)
    # dot: 2 * (8*128) * 128 = 262144 flops, x24 loop trips
    assert h.entry_cost.flops == 2 * 8 * 128 * 128 * 24


def test_collectives_with_groups_and_trips():
    h = HLOAnalysis(SYNTH, num_devices=16)
    # all-gather in loop: out 8*256*4B, group size 2 -> wire (2-1)/2 * bytes
    ag = 8 * 256 * 4 * (1 / 2) * 24
    # all-reduce at entry: 8*128*4B, group {0,1,2,3} size 4 -> 2*(3/4)*bytes
    ar = 2 * 8 * 128 * 4 * (3 / 4)
    got = h.entry_cost.collective_ops
    assert abs(got["all-gather"] - ag) < 1e-6
    assert abs(got["all-reduce"] - ar) < 1e-6


def test_trip_count_ignores_sentinels():
    txt = SYNTH.replace("constant(24)", "constant(2147483647)")
    h = HLOAnalysis(txt, num_devices=16)
    # INT_MAX ignored -> trip count falls back to 1
    assert h.entry_cost.flops == 2 * 8 * 128 * 128


def test_collective_sites_multipliers():
    h = HLOAnalysis(SYNTH, num_devices=16)
    sites = h.collective_sites()
    by_op = {s["op"]: s for s in sites}
    assert by_op["all-gather"]["mult"] == 24.0
    assert by_op["all-reduce"]["mult"] == 1.0
