"""Failure model & graceful degradation (docs/ARCHITECTURE.md):

  * pool-pressure preemption evicts the YOUNGEST in-flight request, never
    the oldest (forward progress), and the preempted request's final
    stream is bit-identical to an unconstrained serve — the requeue is
    ``prompt + tokens-so-far`` recomputed through chunked prefill;
  * deferral-age accounting: a head the pool cannot admit surfaces a
    growing `IterStats.deferral_age` and triggers preemption within
    `preempt_after` iterations instead of silently livelocking;
  * deadlines (`ServeRequest.deadline_s`), `cancel()`, and
    `run()`-exhaustion abort all finish requests honestly with their
    tokens-so-far and drain the page pool;
  * the seeded `FaultInjector` forces admission failure / NaN / Inf
    logits deterministically; the finite-logits guard degrades poisoned
    steps to the XLA oracle path WITHOUT changing the token stream;
  * the no-progress watchdog raises `EngineStallError` (with a
    pool/queue/slot snapshot) instead of spinning to max_iterations, and
    `debug_invariants=True` turns allocator violations into
    `AllocatorInvariantError`.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (AllocatorInvariantError, EngineStallError,
                           FaultInjector, PapiEngine, ServeRequest,
                           parse_fault_specs)
from repro.serving.faults import FAULT_INF, FAULT_NAN, FAULT_NONE


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def draft_model():
    cfg = get_config("qwen2-0.5b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(9))


NO_EOS = get_config("qwen2-0.5b").reduced().vocab_size - 1

# three requests whose page budgets oversubscribe the _tight() pool: two
# fit, the third defers until preemption makes room
PRESSURE_REQS = [([3 + i, 5, 7], 20) for i in range(3)]


def _engine(cfg, params, **kw):
    defaults = dict(max_slots=4, cache_capacity=64, prefill_len=8,
                    alpha=6.0, eos_token=NO_EOS, debug_invariants=True)
    defaults.update(kw)
    return PapiEngine(cfg, params, **defaults)


def _tight(cfg, params, **kw):
    """Paged engine whose pool holds two PRESSURE_REQS but not three."""
    defaults = dict(max_slots=4, cache_capacity=16, kv_layout="paged",
                    page_size=4)
    defaults.update(kw)
    return _engine(cfg, params, **defaults)


def _serve(eng, reqs):
    for i, (prompt, n) in enumerate(reqs):
        eng.submit(ServeRequest(i, list(prompt), max_new_tokens=n))
    return {r.req_id: r for r in eng.run(max_iterations=500)}


def _assert_drained(eng):
    eng.kv.alloc.check()
    assert eng.kv.alloc.mapped_count == 0
    assert eng.kv.alloc.reserved_unmapped == 0
    assert eng.kv.alloc.free_count == eng.kv.alloc.num_pages


# ---------------------------------------------------------------- preemption

@pytest.mark.parametrize("trigger", ["after", "watermark"])
def test_preemption_bit_identical_greedy(small_model, trigger):
    """An oversubscribed pool preempts, and every stream — preempted or
    not — still equals the unconstrained dense serve."""
    cfg, params = small_model
    want = _serve(_engine(cfg, params), PRESSURE_REQS)

    kw = (dict(preempt_after=3) if trigger == "after"
          else dict(preempt_after=None, preempt_watermark=0.5))
    eng = _tight(cfg, params, **kw)
    got = _serve(eng, PRESSURE_REQS)

    assert eng.preemptions >= 1
    assert sum(s.preemptions for s in eng.stats) == eng.preemptions
    for i in range(len(PRESSURE_REQS)):
        assert got[i].tokens == want[i].tokens, i
        assert got[i].finished_reason == "length"
        assert got[i].prompt_len == len(PRESSURE_REQS[i][0])
    _assert_drained(eng)


def test_preemption_bit_identical_speculative(small_model, draft_model):
    """Speculative + paged under preemption: greedy speculation is
    lossless, so even the preempted request's stream (whose window
    alignment the preemption reset) matches the dense plain-greedy serve."""
    cfg, params = small_model
    want = _serve(_engine(cfg, params), PRESSURE_REQS)

    eng = _tight(cfg, params, spec_len=2, draft=draft_model,
                 preempt_after=3)
    got = _serve(eng, PRESSURE_REQS)

    assert eng.preemptions >= 1
    for i in range(len(PRESSURE_REQS)):
        assert got[i].tokens == want[i].tokens, i
    _assert_drained(eng)


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_preemption_bit_identical_paged_mesh(small_model, draft_model):
    """Preemption composes with the mesh: 8-way tensor-parallel paged +
    speculative serving under pool pressure still emits the 1-device
    dense streams."""
    from repro.launch.mesh import make_serving_mesh
    cfg, params = small_model
    want = _serve(_engine(cfg, params), PRESSURE_REQS)

    eng = _tight(cfg, params, spec_len=2, draft=draft_model,
                 preempt_after=3, mesh=make_serving_mesh(1, 8))
    got = _serve(eng, PRESSURE_REQS)

    assert eng.preemptions >= 1
    for i in range(len(PRESSURE_REQS)):
        assert got[i].tokens == want[i].tokens, i
    _assert_drained(eng)


def test_oldest_never_preempted_and_deferral_age_grows(small_model):
    """Satellite: a head the held pool cannot admit surfaces a GROWING
    IterStats.deferral_age and preempts within `preempt_after` iterations
    — and the victim is the youngest, never the oldest."""
    cfg, params = small_model
    K = 4
    eng = _tight(cfg, params, preempt_after=K)
    results = _serve(eng, PRESSURE_REQS)

    ages = [s.deferral_age for s in eng.stats]
    assert max(ages) == K            # grew 1..K, then the preemption fired
    first_defer = next(i for i, a in enumerate(ages) if a == 1)
    assert ages[first_defer:first_defer + K] == list(range(1, K + 1))
    assert eng.stats[first_defer + K - 1].preemptions == 1

    assert 1 in eng.preempted_ids    # youngest of the two in-flight
    assert 0 not in eng.preempted_ids  # oldest always runs to completion
    assert all(r.finished_reason == "length" for r in results.values())
    _assert_drained(eng)


def test_no_preemption_with_single_active(small_model):
    """Forward progress: with one in-flight request there is nothing
    younger to evict — the head waits for it to finish instead of the
    engine thrashing the only request making progress."""
    cfg, params = small_model
    # pool of 8 usable pages: req0's budget (3+20+1 -> 6 pages) fits, but
    # not two of them — req1 defers until req0 finishes
    eng = _tight(cfg, params, cache_capacity=8, preempt_after=2)
    results = _serve(eng, [([3, 5, 7], 20), ([4, 5, 7], 20)])
    assert eng.preemptions == 0
    assert all(len(r.tokens) == 20 and r.finished_reason == "length"
               for r in results.values())
    _assert_drained(eng)


# ------------------------------------------------------ deadlines and cancel

def test_deadline_timeout_in_flight_and_queued(small_model):
    cfg, params = small_model
    eng = _tight(cfg, params, max_slots=1)
    clock = {"now": 0.0}
    eng._now = lambda: clock["now"]
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=30,
                            deadline_s=5.0))
    eng.submit(ServeRequest(1, [4, 5, 7], max_new_tokens=30,
                            deadline_s=5.0))          # queued (1 slot)
    eng.run(max_iterations=3, abort_in_flight=False)
    assert eng.active_slots == [0] and len(eng.queue) == 1

    clock["now"] = 10.0                               # both expire
    res = {r.req_id: r for r in eng.run(max_iterations=10)}
    assert res[0].finished_reason == "timeout"
    assert len(res[0].tokens) >= 1                    # tokens-so-far kept
    assert res[1].finished_reason == "timeout"
    assert res[1].tokens == []                        # never admitted
    _assert_drained(eng)


def test_cancel_queued_and_in_flight(small_model):
    cfg, params = small_model
    eng = _tight(cfg, params, max_slots=1)
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=30))
    eng.submit(ServeRequest(1, [4, 5, 7], max_new_tokens=30))
    eng.run(max_iterations=3, abort_in_flight=False)

    assert eng.cancel(1) is True                      # queued
    assert eng.cancel(0) is True                      # in-flight
    assert eng.cancel(99) is False                    # unknown
    assert eng.cancel(1) is False                     # already finished
    res = {r.req_id: r for r in eng.results}
    assert res[1].finished_reason == "cancelled" and res[1].tokens == []
    assert res[0].finished_reason == "cancelled" and len(res[0].tokens) >= 1
    _assert_drained(eng)


def test_run_exhaustion_aborts_in_flight(small_model):
    """Satellite: iteration exhaustion returns in-flight requests as
    finished_reason='aborted' with tokens-so-far and drains the pool."""
    cfg, params = small_model
    eng = _tight(cfg, params)
    for i in range(2):
        eng.submit(ServeRequest(i, [3 + i, 5, 7], max_new_tokens=20))
    res = {r.req_id: r for r in eng.run(max_iterations=3)}
    assert sorted(res) == [0, 1]
    assert all(r.finished_reason == "aborted" and len(r.tokens) >= 1
               for r in res.values())
    _assert_drained(eng)


# ------------------------------------------------------------ fault injection

def test_injector_deterministic_and_parses():
    a = FaultInjector(seed=3, admit_p=0.4, nan_p=0.3, kernel_p=0.3,
                      latency_p=0.4)
    b = FaultInjector(seed=3, admit_p=0.4, nan_p=0.3, kernel_p=0.3,
                      latency_p=0.4)
    sched = [(a.admission_blocked(i), a.logits_fault(i), a.step_delay(i))
             for i in range(64)]
    assert sched == [(b.admission_blocked(i), b.logits_fault(i),
                      b.step_delay(i)) for i in range(64)]
    assert any(s[0] for s in sched) and any(s[1] != FAULT_NONE for s in sched)
    assert sched != [(c.admission_blocked(i), c.logits_fault(i),
                      c.step_delay(i))
                     for c in [FaultInjector(seed=4, admit_p=0.4, nan_p=0.3,
                                             kernel_p=0.3, latency_p=0.4)]
                     for i in range(64)]

    w = FaultInjector(seed=0, admit_p=1.0, start=2, stop=4)
    assert [w.admission_blocked(i) for i in range(6)] == [
        False, False, True, True, False, False]

    inj = parse_fault_specs(["nan:0.2", "admit"], seed=7)
    assert inj.nan_p == 0.2 and inj.admit_p == 1.0 and inj.seed == 7
    assert parse_fault_specs([]) is None
    with pytest.raises(ValueError):
        parse_fault_specs(["bogus:0.1"])


def test_admission_fault_defers_then_recovers(small_model):
    """Forced allocator admission failure is indistinguishable from pool
    pressure: the head defers (deferral age in IterStats), and once the
    fault window closes every request completes normally."""
    cfg, params = small_model
    # iteration 0 admits two requests; the head then defers through the
    # fault window (iterations 1..3) and keeps deferring on genuine pool
    # pressure until the running requests finish.  Preemption is disabled
    # so the recovery is pure pool drain.
    eng = _tight(cfg, params, preempt_after=None,
                 faults=FaultInjector(seed=0, admit_p=1.0, start=1, stop=4))
    results = _serve(eng, PRESSURE_REQS)
    assert eng.faults.counts["admit"] >= 3
    assert max(s.deferral_age for s in eng.stats) >= 4
    assert all(len(r.tokens) == 20 and r.finished_reason == "length"
               for r in results.values())
    _assert_drained(eng)


@pytest.mark.parametrize("kind", ["nan", "kernel"])
def test_logits_guard_degrades_bit_identical_greedy(small_model, kind):
    """NaN/Inf logits out of the fused step never reach a token: the
    guard re-runs the iteration on the oracle path and the stream is
    bit-identical to the fault-free serve."""
    cfg, params = small_model
    reqs = [([3, 5, 7], 12), ([4, 5], 12)]
    want = _serve(_engine(cfg, params), reqs)

    faults = FaultInjector(seed=5, start=1, stop=8,
                           **{f"{kind}_p": 1.0})
    eng = _engine(cfg, params, faults=faults)
    got = _serve(eng, reqs)

    assert eng.degraded_steps >= 1
    assert eng.faults.counts[kind] >= 1
    assert sum(s.degraded for s in eng.stats) == eng.degraded_steps
    for i in range(len(reqs)):
        assert got[i].tokens == want[i].tokens, i


def test_logits_guard_degrades_bit_identical_speculative(small_model,
                                                         draft_model):
    """Degrading a poisoned verify step clamps the window to one oracle
    decode; the draft cache stays in lockstep and the stream still equals
    the fault-free (and plain-greedy) serve."""
    cfg, params = small_model
    reqs = [([3, 5, 7], 12), ([4, 5], 12)]
    want = _serve(_engine(cfg, params), reqs)

    eng = _engine(cfg, params, spec_len=2, draft=draft_model,
                  faults=FaultInjector(seed=5, nan_p=0.5, start=1, stop=8))
    got = _serve(eng, reqs)

    assert eng.degraded_steps >= 1
    for i in range(len(reqs)):
        assert got[i].tokens == want[i].tokens, i


def test_latency_fault_trips_deadline(small_model):
    """Artificial step latency + a tight deadline: the slowed request
    times out honestly instead of finishing late."""
    cfg, params = small_model
    eng = _tight(cfg, params,
                 faults=FaultInjector(seed=0, latency_p=1.0,
                                      latency_s=0.05))
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=200,
                            deadline_s=0.15))
    res = eng.run(max_iterations=50)
    assert eng.faults.counts["latency"] >= 1
    assert res[0].finished_reason == "timeout"
    _assert_drained(eng)


# --------------------------------------------------- watchdog and invariants

def test_watchdog_raises_structured_stall_error(small_model):
    """A head that can NEVER be admitted (and nothing to preempt) must
    raise EngineStallError with a diagnostic snapshot, not spin to
    max_iterations."""
    cfg, params = small_model
    eng = _tight(cfg, params, stall_limit=5)
    eng.kv.can_admit = lambda *_: False
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=4))
    with pytest.raises(EngineStallError) as err:
        eng.run(max_iterations=100)
    snap = err.value.snapshot
    assert snap["queue"] == [0]
    assert snap["deferral_age"] >= 5
    assert snap["pool"]["free"] == eng.kv.alloc.num_pages
    assert eng.iteration < 100       # raised well before exhaustion


def test_debug_invariants_raises_structured_error(small_model):
    """debug_invariants=True turns an allocator violation (here: a mapped
    page forced back onto the free list) into AllocatorInvariantError
    carrying the allocator snapshot."""
    cfg, params = small_model
    eng = _tight(cfg, params)
    eng.submit(ServeRequest(0, [3, 5, 7], max_new_tokens=30))
    eng.run(max_iterations=2, abort_in_flight=False)
    assert eng.active_slots == [0]

    eng.kv.alloc._free.append(eng.kv.alloc.pages_of(0)[0])
    with pytest.raises(AllocatorInvariantError) as err:
        eng.step()
    assert "invariant" in str(err.value)
    assert err.value.snapshot["pool"]["mapped"]


# ------------------------------------------------------- streaming front end

def _serve_live(eng, sched):
    streams: dict[int, list[int]] = {}
    finals = {}
    for ev in eng.serve(sched):
        if ev.finished:
            finals[ev.req_id] = ev.result
        else:
            streams.setdefault(ev.req_id, []).append(ev.token)
    return streams, finals


def test_serve_preemption_streams_bit_identical(small_model):
    """Pool-pressure preemption mid-STREAM: every live stream — the
    preempted request included — still equals the unconstrained dense
    offline serve, nothing is re-streamed, and the pool drains."""
    cfg, params = small_model
    want = _serve(_engine(cfg, params), PRESSURE_REQS)

    eng = _tight(cfg, params, preempt_after=3)
    sched = [[ServeRequest(i, list(p), max_new_tokens=n)]
             for i, (p, n) in enumerate(PRESSURE_REQS)]
    streams, finals = _serve_live(eng, sched)

    assert eng.preemptions >= 1
    for i in range(len(PRESSURE_REQS)):
        assert streams[i] == want[i].tokens, i
        assert finals[i].finished_reason == "length"
    _assert_drained(eng)


def test_serve_cancel_and_timeout_mid_stream(small_model):
    """PR 6 semantics through the streaming front end: a QUEUED request
    times out without ever streaming a token; an in-flight cancel ends the
    stream with reason 'cancelled', keeping the tokens already streamed."""
    cfg, params = small_model
    eng = _engine(cfg, params, max_slots=1)
    clock = {"now": 0.0}
    eng._now = lambda: clock["now"]
    sched = [[ServeRequest(0, [3, 5, 7], max_new_tokens=60)],
             [ServeRequest(1, [4, 5, 7], max_new_tokens=30,
                           deadline_s=5.0)]]
    streams: dict[int, list[int]] = {}
    finals = {}
    for ev in eng.serve(sched):
        if ev.finished:
            finals[ev.req_id] = ev.result
            continue
        streams.setdefault(ev.req_id, []).append(ev.token)
        if ev.req_id == 0 and len(streams[0]) == 4:
            clock["now"] = 10.0            # expire the queued deadline
            assert eng.cancel(0) is True   # cancel the one mid-stream

    assert finals[0].finished_reason == "cancelled"
    assert len(streams[0]) >= 4
    assert finals[0].tokens == streams[0]
    assert finals[1].finished_reason == "timeout"
    assert finals[1].tokens == [] and 1 not in streams
