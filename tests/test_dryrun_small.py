"""Dry-run machinery test at reduced scale: 16 fake devices, reduced archs,
full lower+compile through the real build_step/dryrun path.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes
(the main pytest process keeps its single CPU device)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
from repro.configs import get_config, SHAPES
from repro.configs.base import ShapeCell
from repro.launch.steps import build_step, lower_step
from repro.launch.hlo import HLOAnalysis

mesh = jax.make_mesh((4, 4), ("data", "model"))
out = {}
for arch, kind in [("qwen2-0.5b", "train"), ("mamba2-1.3b", "decode"),
                   ("olmoe-1b-7b", "prefill")]:
    cfg = get_config(arch).reduced()
    cell = ShapeCell("t", 64, 8, kind)
    built = build_step(cfg, cell, mesh)
    lowered = lower_step(built, mesh)
    compiled = lowered.compile()
    h = HLOAnalysis(compiled.as_text(), 16)
    out[arch] = {
        "flops": h.entry_cost.flops,
        "wire": h.entry_cost.collective_bytes,
        "mem": int(compiled.memory_analysis().temp_size_in_bytes),
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_all_kinds():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for arch, stats in out.items():
        assert stats["flops"] > 0, arch          # dots found + counted
        assert stats["wire"] > 0, arch           # sharded => collectives
