"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import (
    decode_step,
    forward_train,
    init_cache,
    init_params,
    param_logical_axes,
    prefill,
)

jax.config.update("jax_enable_x64", False)

BATCH, SEQ = 2, 64


def make_train_batch(cfg, key):
    b, s = BATCH, SEQ
    ks = jax.random.split(key, 4)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(ks[0], (b, s, cfg.d_model), jnp.float32),
            "mask": jax.random.bernoulli(ks[1], 0.3, (b, s)),
            "targets": jax.random.randint(ks[2], (b, s), 0, cfg.vocab_size),
            "target_mask": jax.random.bernoulli(ks[1], 0.3, (b, s)).astype(jnp.float32),
        }
    if cfg.family == "vlm":
        sv = s // 4
        st = s - sv
        return {
            "tokens": jax.random.randint(ks[0], (b, st), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(ks[1], (b, sv, cfg.d_model), jnp.float32),
            "positions": jnp.broadcast_to(jnp.arange(s)[None, None, :], (b, 3, s)),
            "targets": jax.random.randint(ks[2], (b, st), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("name", [c.name for c in ASSIGNED])
def test_train_step_smoke(name):
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_train_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(
        lambda p, b: forward_train(cfg, p, b, remat=False)
    )(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    assert float(loss) > 0
    # one grad step must also be finite
    g = jax.grad(lambda p: forward_train(cfg, p, batch, remat=False)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in leaves), name


@pytest.mark.parametrize(
    "name", [c.name for c in ASSIGNED if c.has_decode_step]
)
def test_prefill_decode_smoke(name):
    cfg = get_config(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, p_len, cap = 2, 16, 48
    cache = init_cache(cfg, b, cap)
    key = jax.random.PRNGKey(2)
    if cfg.family == "vlm":
        sv = p_len // 4
        batch = {
            "tokens": jax.random.randint(key, (b, p_len - sv), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(key, (b, sv, cfg.d_model), jnp.float32),
            "positions": jnp.broadcast_to(
                jnp.arange(p_len)[None, None, :], (b, 3, p_len)
            ),
        }
    else:
        batch = {"tokens": jax.random.randint(key, (b, p_len), 0, cfg.vocab_size)}
    logits, cache = prefill(cfg, params, batch, cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # a few decode steps
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(3):
        logits2, cache = decode_step(cfg, params, cache, tok)
        assert logits2.shape == (b, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
        tok = jnp.argmax(logits2[:, -1], -1)[:, None]
    assert int(cache["pos"][0]) == p_len + 3


@pytest.mark.parametrize(
    "name", ["qwen2-0.5b", "mamba2-1.3b", "zamba2-1.2b", "olmoe-1b-7b"]
)
def test_decode_matches_parallel_forward(name):
    """Teacher-forced decode must reproduce the parallel (train-mode) logits —
    the cache path and the parallel path are the same function."""
    cfg = get_config(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)

    from repro.models.model import backbone, embed_inputs, lm_logits
    h, positions = embed_inputs(cfg, params, {"tokens": toks})
    h, _, _ = backbone(cfg, params, h, positions, None, "train")
    ref_logits = lm_logits(cfg, params, h)            # [b, s, V]

    cache = init_cache(cfg, b, s + 4)
    outs = []
    for i in range(s):
        lg, cache = decode_step(cfg, params, cache, toks[:, i : i + 1])
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )
