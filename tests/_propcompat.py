"""Property-test compatibility layer: hypothesis when installed, a small
deterministic fallback otherwise.

The tier-1 suite must collect and run everywhere — including minimal
containers where `hypothesis` cannot be installed.  A plain
`pytest.importorskip("hypothesis")` at module top would skip *entire* test
modules (losing every non-property test in them), so instead the property
tests import `given/settings/st` from here:

  * with hypothesis installed, this re-exports the real thing — full
    shrinking, health checks, the works (CI installs it via
    `requirements.txt` / `pyproject.toml`'s `[test]` extra);
  * without it, a deterministic mini-runner draws `max_examples` samples
    (capped at `_FALLBACK_CAP`) from a seeded RNG per test, so the property
    tests still execute meaningful cases instead of silently skipping.

Only the strategy surface this repo uses is implemented: `integers`,
`floats`, `lists`, `sampled_from`.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_CAP = 50       # keep the no-hypothesis suite fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            n = min(getattr(fn, "_max_examples", 20), _FALLBACK_CAP)
            seed = zlib.crc32(fn.__qualname__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            # don't let pytest see the wrapped signature: the drawn params
            # would look like undefined fixtures
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
