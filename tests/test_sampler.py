"""First tests for the temperature / top-k sampling path.

`sampler.sample` had no coverage at all; notably `top_k >= vocab` indexed
`logits[..., -top_k]` out of range and crashed — a no-op filter is the
correct semantics (every token survives).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import greedy, sample

V = 16
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def logits():
    return jax.random.normal(jax.random.PRNGKey(7), (3, V), jnp.float32)


def test_zero_temperature_is_greedy(logits):
    out = sample(logits, KEY, temperature=0.0, top_k=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(greedy(logits)))
    assert out.dtype == jnp.int32


def test_top_k_one_is_greedy_for_any_key(logits):
    """With only the argmax surviving the filter, the categorical draw is
    deterministic regardless of the key."""
    for seed in range(5):
        out = sample(logits, jax.random.PRNGKey(seed), temperature=0.7,
                     top_k=1)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(greedy(logits)))


@pytest.mark.parametrize("top_k", [V, V + 1, 10 * V])
def test_top_k_at_or_beyond_vocab_is_a_noop_filter(logits, top_k):
    """top_k >= vocab used to index logits[..., -top_k] out of range; it
    must behave exactly like top_k disabled (same key => same draw)."""
    got = sample(logits, KEY, temperature=1.0, top_k=top_k)
    want = sample(logits, KEY, temperature=1.0, top_k=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.all((np.asarray(got) >= 0) & (np.asarray(got) < V))


def test_sampled_tokens_always_inside_top_k_set(logits):
    k = 3
    topk = np.argsort(np.asarray(logits), axis=-1)[:, -k:]
    for seed in range(20):
        out = np.asarray(sample(logits, jax.random.PRNGKey(seed),
                                temperature=1.3, top_k=k))
        for row in range(logits.shape[0]):
            assert out[row] in topk[row], (row, out[row], topk[row])


def test_temperature_sharpens_distribution():
    """A mild logit gap becomes near-deterministic at low temperature and
    stays diverse at high temperature."""
    logits = jnp.asarray([[0.0, 1.0, 0.5, -0.5]])
    cold = {int(sample(logits, jax.random.PRNGKey(s), temperature=0.05)[0])
            for s in range(25)}
    hot = {int(sample(logits, jax.random.PRNGKey(s), temperature=50.0)[0])
           for s in range(25)}
    assert cold == {1}
    assert len(hot) > 1
