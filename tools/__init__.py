# Marks tools/ as a package so `python -m tools.papilint` resolves from the
# repo root.  The standalone scripts in this directory (check_bench.py,
# trace_report.py, ...) are still run as plain files.
