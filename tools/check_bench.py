"""BENCH regression gate: fail CI when the tracked benchmark file regresses.

Two checks over BENCH_engine.json (written/merged by
`benchmarks/engine_hotpath.py`):

  1. every ``tokens_bit_identical`` flag, anywhere in the file, is true —
     the A/B sections (--mesh, --kv paged, --long-prompt, the
     paged_spec_attn_pim kernel A/B) gate their own runs, but this catches
     a stale file whose sections were merged across runs;
  2. ``paged.modes.speculative.paged_tok_per_s`` stays at or above
     PAGED_SPEC_FLOOR of the dense speculative baseline recorded in the
     same section — the regression this guards is the one ISSUE 5 closed:
     speculative verify windows falling off the kernel/equal-context path
     and back onto a pool-wide `gather_kv_pages` view per decode step;
  3. the ``pressure`` section (the --pressure oversubscribed trace) shows
     every request COMPLETED and a p99 first-admission delay at or below
     PRESSURE_DELAY_CEIL iterations — the regression this guards is
     pool-pressure preemption silently dying and the queue head deferring
     indefinitely behind long-running requests (its
     ``tokens_bit_identical`` flag rides check 1);
  4. the ``arrivals`` section (the --arrivals continuous-batching trace)
     shows, for EVERY serving combo (greedy/speculative x dense/paged),
     all requests completed and a p99 TTFT at or below ARRIVALS_TTFT_CEIL
     iterations — the regressions this guards are the serve loop losing or
     stalling queued requests under live load and admission waves starving
     first tokens (streamed-vs-oracle identity rides check 1);
  5. the ``telemetry`` section (the --arrivals --trace observation A/B)
     shows a traced median per-iteration wall within
     TELEMETRY_OVERHEAD_CEIL of the untraced run and zero events dropped
     from the ring — the regressions this guards are the tracer hooks
     creeping onto the untraced hot path and the traced path growing a
     real per-dispatch cost (its ``tokens_bit_identical`` flag — tracing
     must never perturb streams — rides check 1).

Usage:  python tools/check_bench.py [path/to/BENCH_engine.json]
Exits non-zero with a message on the first violated check.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

# paged speculative must hold >= 80% of the dense speculative tok/s
# recorded in the same BENCH section (acceptance measured ~0.98x; 0.8
# leaves headroom for CI-runner noise without letting the gather creep
# back)
PAGED_SPEC_FLOOR = 0.8

# p99 first-admission delay ceiling (iterations) for the --pressure trace.
# Measured 27 on the 6x-oversubscribed 12-request trace (preempt_after=3);
# the trace is deterministic, so 60 is pure headroom against future trace
# tweaks — a dead preemption path shows up as hundreds of iterations (the
# head waits for full pool drains) or an outright incomplete run.
PRESSURE_DELAY_CEIL = 60

# p99 TTFT ceiling (iterations) for the --arrivals Poisson trace.  The
# schedule is seeded, so the iteration-valued TTFT is deterministic:
# measured p99 of 2 iterations across all four combos at rate 0.5 with 4
# slots; 16 is pure headroom against trace tweaks — a starved admission
# path (prefill stalling behind decodes, or waves never draining the
# queue) shows up as tens of iterations.
ARRIVALS_TTFT_CEIL = 16

# Traced-vs-untraced overhead ceiling for the --trace telemetry A/B: the
# traced spec_dense run's median per-iteration wall may exceed the
# untraced run's by at most this fraction.  The traced path adds one
# perf_counter pair + block_until_ready per dispatch — the untraced
# engine already syncs every iteration through `_fetch`, so the honest
# cost is bookkeeping, not a device sync.  Median over post-warmup decode
# iterations keeps CI-runner noise out of the ratio.
TELEMETRY_OVERHEAD_CEIL = 0.05


def iter_identity_flags(node, path=""):
    if isinstance(node, dict):
        for key, val in node.items():
            sub = f"{path}.{key}" if path else key
            if key == "tokens_bit_identical":
                yield sub, val
            else:
                yield from iter_identity_flags(val, sub)
    elif isinstance(node, list):
        for i, val in enumerate(node):
            yield from iter_identity_flags(val, f"{path}[{i}]")


def main() -> int:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else (
        Path(__file__).resolve().parent.parent / "BENCH_engine.json")
    if not path.exists():
        print(f"check_bench: {path} not found (run "
              "benchmarks/engine_hotpath.py first)")
        return 1
    bench = json.loads(path.read_text())

    failures = []
    flags = list(iter_identity_flags(bench))
    if not flags:
        failures.append("no tokens_bit_identical flags found — the A/B "
                        "sections are missing")
    for where, ok in flags:
        if ok is not True:
            failures.append(f"{where} is {ok!r} (token streams diverged)")

    try:
        spec = bench["paged"]["modes"]["speculative"]
        paged, dense = spec["paged_tok_per_s"], spec["dense_tok_per_s"]
    except KeyError as missing:
        failures.append(f"paged.modes.speculative section incomplete "
                        f"(missing {missing})")
    else:
        if paged < PAGED_SPEC_FLOOR * dense:
            failures.append(
                f"paged speculative regressed: {paged:.1f} tok/s < "
                f"{PAGED_SPEC_FLOOR:.0%} of the dense baseline "
                f"{dense:.1f} tok/s (ratio {paged / dense:.2f})")
        else:
            print(f"paged speculative: {paged:.1f} tok/s = "
                  f"{paged / dense:.2f}x dense ({dense:.1f} tok/s), floor "
                  f"{PAGED_SPEC_FLOOR:.2f} — OK")

    try:
        pressure = bench["pressure"]
        done, total = pressure["completed"], pressure["requests"]
        p99 = pressure["admission_delay_p99"]
    except KeyError as missing:
        failures.append(f"pressure section incomplete or absent "
                        f"(missing {missing}) — run "
                        "benchmarks/engine_hotpath.py --pressure")
    else:
        if done < total:
            failures.append(f"pressure trace lost requests: {done}/{total} "
                            "completed")
        if p99 > PRESSURE_DELAY_CEIL:
            failures.append(
                f"pressure admission delay unbounded: p99 {p99} iterations "
                f"> ceiling {PRESSURE_DELAY_CEIL} (preemption not relieving "
                "the deferring head?)")
        if not failures:
            print(f"pressure: {done}/{total} completed, admission delay "
                  f"p99 {p99} <= {PRESSURE_DELAY_CEIL} iterations — OK")

    try:
        arrivals = bench["arrivals"]
        total = arrivals["requests"]
        modes = arrivals["modes"]
    except KeyError as missing:
        failures.append(f"arrivals section incomplete or absent "
                        f"(missing {missing}) — run "
                        "benchmarks/engine_hotpath.py --arrivals 0.5")
    else:
        bad = False
        for label, mode in sorted(modes.items()):
            done = mode.get("completed", 0)
            ttft = mode.get("ttft_iters_p99")
            if done < total:
                failures.append(f"arrivals/{label} lost requests: "
                                f"{done}/{total} completed under live load")
                bad = True
            if ttft is None or ttft > ARRIVALS_TTFT_CEIL:
                failures.append(
                    f"arrivals/{label} TTFT unbounded: p99 {ttft} iterations "
                    f"> ceiling {ARRIVALS_TTFT_CEIL} (admission waves "
                    "starving first tokens?)")
                bad = True
        if not bad and modes:
            worst = max(m["ttft_iters_p99"] for m in modes.values())
            print(f"arrivals: {len(modes)} combos completed {total}/{total}, "
                  f"worst p99 TTFT {worst:.0f} <= {ARRIVALS_TTFT_CEIL} "
                  "iterations — OK")
        elif not modes:
            failures.append("arrivals section has no modes")

    try:
        tel = bench["telemetry"]
        overhead = tel["overhead_frac"]
        dropped = tel["events_dropped"]
    except KeyError as missing:
        failures.append(f"telemetry section incomplete or absent "
                        f"(missing {missing}) — run "
                        "benchmarks/engine_hotpath.py --arrivals 0.5 "
                        "--trace trace.telemetry.json")
    else:
        if overhead > TELEMETRY_OVERHEAD_CEIL:
            failures.append(
                f"tracing overhead regressed: traced median wall "
                f"{overhead:+.1%} over untraced > ceiling "
                f"{TELEMETRY_OVERHEAD_CEIL:.0%} (timed_call grew a real "
                "per-dispatch cost?)")
        if dropped:
            failures.append(
                f"telemetry ring dropped {dropped} events on the bench "
                "trace — capacity no longer covers a short serve run")
        if overhead <= TELEMETRY_OVERHEAD_CEIL and not dropped:
            print(f"telemetry: traced wall {overhead:+.1%} vs untraced "
                  f"(ceiling {TELEMETRY_OVERHEAD_CEIL:.0%}), "
                  f"{tel.get('events', '?')} events, 0 dropped — OK")

    if failures:
        for f in failures:
            print(f"check_bench FAIL: {f}")
        return 1
    print(f"check_bench: {len(flags)} identity flags true, paged "
          "speculative above floor, pressure trace bounded, arrivals "
          "trace completed within the TTFT ceiling, telemetry overhead "
          "under the ceiling")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
