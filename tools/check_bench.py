"""BENCH regression gate: fail CI when the tracked benchmark file regresses.

Checks over BENCH_engine.json (written/merged by
`benchmarks/engine_hotpath.py`):

  1. every ``tokens_bit_identical`` flag, anywhere in the file, is true —
     the A/B sections (--mesh, --kv paged, --long-prompt, the
     paged_spec_attn_pim kernel A/B) gate their own runs, but this catches
     a stale file whose sections were merged across runs;
  2. ``paged.modes.speculative.paged_tok_per_s`` stays at or above
     PAGED_SPEC_FLOOR of the dense speculative baseline recorded in the
     same section — the regression this guards is the one ISSUE 5 closed:
     speculative verify windows falling off the kernel/equal-context path
     and back onto a pool-wide `gather_kv_pages` view per decode step;
  3. the ``pressure`` section (the --pressure oversubscribed trace) shows
     every request COMPLETED and a p99 first-admission delay at or below
     PRESSURE_DELAY_CEIL iterations — the regression this guards is
     pool-pressure preemption silently dying and the queue head deferring
     indefinitely behind long-running requests (its
     ``tokens_bit_identical`` flag rides check 1);
  4. the ``arrivals`` section (the --arrivals continuous-batching trace)
     shows, for EVERY serving combo (greedy/speculative x dense/paged),
     all requests completed and a p99 TTFT at or below ARRIVALS_TTFT_CEIL
     iterations — the regressions this guards are the serve loop losing or
     stalling queued requests under live load and admission waves starving
     first tokens (streamed-vs-oracle identity rides check 1);
  5. the ``telemetry`` section (the --arrivals --trace observation A/B)
     shows a traced median per-iteration wall within
     TELEMETRY_OVERHEAD_CEIL of the untraced run and zero events dropped
     from the ring — the regressions this guards are the tracer hooks
     creeping onto the untraced hot path and the traced path growing a
     real per-dispatch cost (its ``tokens_bit_identical`` flag — tracing
     must never perturb streams — rides check 1);
  6. the ``sanitize`` section (the --sanitize runtime-sanitizer smoke)
     shows, for every recorded mode, at least one steady-state iteration,
     EXACTLY ``transfer_budget`` host transfers per steady fused decode
     iteration, and zero steady-state recompiles — the regressions this
     guards are an un-batched sync creeping onto the hot path and a flag
     flip retracing under an existing jit-cache key (the seed bug PL003
     checks statically);
  7. the ``recovery`` section (the --crash-recovery durability gate)
     shows, for EVERY serving combo (greedy/speculative x dense/paged),
     all requests completed across the crash, ZERO duplicate finishes,
     and recovered token streams bit-identical to the uninterrupted
     oracle — the regressions this guards are the write-ahead journal
     losing committed tokens, replay re-emitting a finished request, and
     the resumed-prefill path drifting off the deterministic re-decode.

A missing or truncated section is reported as a named-section failure
("BENCH section 'X' missing ...") with the engine_hotpath invocation that
produces it — never as a raw KeyError traceback.

Usage:  python tools/check_bench.py [path/to/BENCH_engine.json]
Exits non-zero with a message on the first violated check.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

# paged speculative must hold >= 80% of the dense speculative tok/s
# recorded in the same BENCH section (acceptance measured ~0.98x; 0.8
# leaves headroom for CI-runner noise without letting the gather creep
# back)
PAGED_SPEC_FLOOR = 0.8

# p99 first-admission delay ceiling (iterations) for the --pressure trace.
# Measured 27 on the 6x-oversubscribed 12-request trace (preempt_after=3);
# the trace is deterministic, so 60 is pure headroom against future trace
# tweaks — a dead preemption path shows up as hundreds of iterations (the
# head waits for full pool drains) or an outright incomplete run.
PRESSURE_DELAY_CEIL = 60

# p99 TTFT ceiling (iterations) for the --arrivals Poisson trace.  The
# schedule is seeded, so the iteration-valued TTFT is deterministic:
# measured p99 of 2 iterations across all four combos at rate 0.5 with 4
# slots; 16 is pure headroom against trace tweaks — a starved admission
# path (prefill stalling behind decodes, or waves never draining the
# queue) shows up as tens of iterations.
ARRIVALS_TTFT_CEIL = 16

# Traced-vs-untraced overhead ceiling for the --trace telemetry A/B: the
# traced spec_dense run's median per-iteration wall may exceed the
# untraced run's by at most this fraction.  The traced path adds one
# perf_counter pair + block_until_ready per dispatch — the untraced
# engine already syncs every iteration through `_fetch`, so the honest
# cost is bookkeeping, not a device sync.  Median over post-warmup decode
# iterations keeps CI-runner noise out of the ratio.
TELEMETRY_OVERHEAD_CEIL = 0.05


def iter_identity_flags(node, path=""):
    if isinstance(node, dict):
        for key, val in node.items():
            sub = f"{path}.{key}" if path else key
            if key == "tokens_bit_identical":
                yield sub, val
            else:
                yield from iter_identity_flags(val, sub)
    elif isinstance(node, list):
        for i, val in enumerate(node):
            yield from iter_identity_flags(val, f"{path}[{i}]")


def get_section(bench, dotted: str, hint: str, failures: list):
    """Walk a dotted path into the bench dict.

    On the first missing component, append a named-section failure (which
    component of which section, plus the invocation that writes it) and
    return None — callers never see a KeyError.
    """
    node = bench
    seen: list[str] = []
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            at = ".".join(seen + [part])
            failures.append(
                f"BENCH section '{dotted}' missing ('{at}' not found) — "
                f"run {hint}")
            return None
        seen.append(part)
        node = node[part]
    return node


def need_keys(section, name: str, keys: list, hint: str,
              failures: list) -> bool:
    """Require leaf keys inside an already-located section."""
    missing = [k for k in keys if k not in section]
    if missing:
        failures.append(
            f"BENCH section '{name}' incomplete (missing "
            f"{', '.join(missing)}) — run {hint}")
        return False
    return True


def main() -> int:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else (
        Path(__file__).resolve().parent.parent / "BENCH_engine.json")
    if not path.exists():
        print(f"check_bench: {path} not found (run "
              "benchmarks/engine_hotpath.py first)")
        return 1
    bench = json.loads(path.read_text())

    failures = []
    flags = list(iter_identity_flags(bench))
    if not flags:
        failures.append("no tokens_bit_identical flags found — the A/B "
                        "sections are missing")
    for where, ok in flags:
        if ok is not True:
            failures.append(f"{where} is {ok!r} (token streams diverged)")

    hint = "benchmarks/engine_hotpath.py --kv paged"
    spec = get_section(bench, "paged.modes.speculative", hint, failures)
    if spec is not None and need_keys(
            spec, "paged.modes.speculative",
            ["paged_tok_per_s", "dense_tok_per_s"], hint, failures):
        paged, dense = spec["paged_tok_per_s"], spec["dense_tok_per_s"]
        if paged < PAGED_SPEC_FLOOR * dense:
            failures.append(
                f"paged speculative regressed: {paged:.1f} tok/s < "
                f"{PAGED_SPEC_FLOOR:.0%} of the dense baseline "
                f"{dense:.1f} tok/s (ratio {paged / dense:.2f})")
        else:
            print(f"paged speculative: {paged:.1f} tok/s = "
                  f"{paged / dense:.2f}x dense ({dense:.1f} tok/s), floor "
                  f"{PAGED_SPEC_FLOOR:.2f} — OK")

    hint = "benchmarks/engine_hotpath.py --pressure"
    pressure = get_section(bench, "pressure", hint, failures)
    if pressure is not None and need_keys(
            pressure, "pressure",
            ["completed", "requests", "admission_delay_p99"],
            hint, failures):
        done, total = pressure["completed"], pressure["requests"]
        p99 = pressure["admission_delay_p99"]
        ok = True
        if done < total:
            failures.append(f"pressure trace lost requests: {done}/{total} "
                            "completed")
            ok = False
        if p99 > PRESSURE_DELAY_CEIL:
            failures.append(
                f"pressure admission delay unbounded: p99 {p99} iterations "
                f"> ceiling {PRESSURE_DELAY_CEIL} (preemption not relieving "
                "the deferring head?)")
            ok = False
        if ok:
            print(f"pressure: {done}/{total} completed, admission delay "
                  f"p99 {p99} <= {PRESSURE_DELAY_CEIL} iterations — OK")

    hint = "benchmarks/engine_hotpath.py --arrivals 0.5"
    arrivals = get_section(bench, "arrivals", hint, failures)
    if arrivals is not None and need_keys(
            arrivals, "arrivals", ["requests", "modes"], hint, failures):
        total = arrivals["requests"]
        modes = arrivals["modes"]
        bad = False
        for label, mode in sorted(modes.items()):
            done = mode.get("completed", 0)
            ttft = mode.get("ttft_iters_p99")
            if done < total:
                failures.append(f"arrivals/{label} lost requests: "
                                f"{done}/{total} completed under live load")
                bad = True
            if ttft is None or ttft > ARRIVALS_TTFT_CEIL:
                failures.append(
                    f"arrivals/{label} TTFT unbounded: p99 {ttft} iterations "
                    f"> ceiling {ARRIVALS_TTFT_CEIL} (admission waves "
                    "starving first tokens?)")
                bad = True
        if not bad and modes:
            worst = max(m["ttft_iters_p99"] for m in modes.values())
            print(f"arrivals: {len(modes)} combos completed {total}/{total}, "
                  f"worst p99 TTFT {worst:.0f} <= {ARRIVALS_TTFT_CEIL} "
                  "iterations — OK")
        elif not modes:
            failures.append("BENCH section 'arrivals' has no modes — run "
                            f"{hint}")

    hint = ("benchmarks/engine_hotpath.py --arrivals 0.5 "
            "--trace trace.telemetry.json")
    tel = get_section(bench, "telemetry", hint, failures)
    if tel is not None and need_keys(
            tel, "telemetry", ["overhead_frac", "events_dropped"],
            hint, failures):
        overhead = tel["overhead_frac"]
        dropped = tel["events_dropped"]
        if overhead > TELEMETRY_OVERHEAD_CEIL:
            failures.append(
                f"tracing overhead regressed: traced median wall "
                f"{overhead:+.1%} over untraced > ceiling "
                f"{TELEMETRY_OVERHEAD_CEIL:.0%} (timed_call grew a real "
                "per-dispatch cost?)")
        if dropped:
            failures.append(
                f"telemetry ring dropped {dropped} events on the bench "
                "trace — capacity no longer covers a short serve run")
        if overhead <= TELEMETRY_OVERHEAD_CEIL and not dropped:
            print(f"telemetry: traced wall {overhead:+.1%} vs untraced "
                  f"(ceiling {TELEMETRY_OVERHEAD_CEIL:.0%}), "
                  f"{tel.get('events', '?')} events, 0 dropped — OK")

    hint = "benchmarks/engine_hotpath.py --sanitize"
    san = get_section(bench, "sanitize", hint, failures)
    if san is not None:
        if not san:
            failures.append(f"BENCH section 'sanitize' has no modes — run "
                            f"{hint}")
        for label, rep in sorted(san.items()):
            name = f"sanitize.{label}"
            if not need_keys(rep, name,
                             ["transfer_budget", "steady_iterations",
                              "transfers_per_steady_iter", "recompiles"],
                             hint, failures):
                continue
            ok = True
            if rep["steady_iterations"] <= 0:
                failures.append(
                    f"{name}: no steady-state iterations recorded — the "
                    "sanitized run never reached fused decode-only steps")
                ok = False
            if rep["transfers_per_steady_iter"] != rep["transfer_budget"]:
                failures.append(
                    f"{name}: {rep['transfers_per_steady_iter']:.2f} host "
                    f"transfers per steady iteration != budget "
                    f"{rep['transfer_budget']} — an un-batched sync crept "
                    "onto the hot path")
                ok = False
            if rep["recompiles"] != 0:
                failures.append(
                    f"{name}: {rep['recompiles']} steady-state recompiles "
                    "(a flag flip retraced under an existing jit-cache key)")
                ok = False
            if ok:
                print(f"{name}: {rep['steady_iterations']} steady "
                      f"iterations at exactly {rep['transfer_budget']} "
                      "transfer(s)/iter, 0 recompiles — OK")

    hint = "benchmarks/engine_hotpath.py --crash-recovery"
    rec = get_section(bench, "recovery", hint, failures)
    if rec is not None and need_keys(
            rec, "recovery", ["crash_points", "modes"], hint, failures):
        modes = rec["modes"]
        if not modes:
            failures.append(f"BENCH section 'recovery' has no modes — run "
                            f"{hint}")
        bad = False
        for label, mode in sorted(modes.items()):
            name = f"recovery.{label}"
            if not need_keys(mode, name,
                             ["completed", "duplicate_finishes",
                              "tokens_bit_identical"], hint, failures):
                bad = True
                continue
            if mode["completed"] is not True:
                failures.append(
                    f"{name}: requests lost across the crash (journal "
                    "replay dropped a submit/commit?)")
                bad = True
            if mode["duplicate_finishes"] != 0:
                failures.append(
                    f"{name}: {mode['duplicate_finishes']} duplicate "
                    "finish(es) — a request was re-emitted after its "
                    "finish record was already durable")
                bad = True
            # tokens_bit_identical itself rides check 1; report the
            # per-mode context here so the failure names the combo.
            if mode["tokens_bit_identical"] is not True:
                failures.append(
                    f"{name}: recovered streams diverged from the "
                    "uninterrupted oracle")
                bad = True
        if not bad and modes:
            print(f"recovery: {len(modes)} combos survived crashes at "
                  f"{rec['crash_points']} with exactly-once finishes and "
                  "bit-identical streams — OK")

    if failures:
        for f in failures:
            print(f"check_bench FAIL: {f}")
        return 1
    print(f"check_bench: {len(flags)} identity flags true, paged "
          "speculative above floor, pressure trace bounded, arrivals "
          "trace completed within the TTFT ceiling, telemetry overhead "
          "under the ceiling, sanitize budgets exact, crash recovery "
          "exactly-once and bit-identical")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
