#!/usr/bin/env python
"""Fail CI on broken *relative* links in the repo's markdown files.

Checks every ``[text](target)`` whose target is not an absolute URL or a
pure in-page anchor, resolving it against the file that contains it.  Run
from anywhere:

    python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}


def md_files() -> list[Path]:
    return [p for p in ROOT.rglob("*.md")
            if not SKIP_DIRS & set(part for part in p.parts)]


def check(path: Path) -> list[str]:
    errors = []
    for m in LINK.finditer(path.read_text(encoding="utf-8")):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main() -> int:
    errors = [e for p in md_files() for e in check(p)]
    for e in errors:
        print(e)
    files = len(md_files())
    print(f"checked {files} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
