"""papilint checkers PL001-PL005.

Each per-file checker takes ``(tree, source, relpath, config, annotations)``
and returns a list of Violations; the cross-file PL005 checks take the
config and repo root.  All analysis is pure-AST (stdlib only) so the
suite runs before any dependency is installed.
"""
from __future__ import annotations

import ast
from pathlib import Path

from tools.papilint.config import Config
from tools.papilint.core import Annotations, Violation

HOST = "host"
DEVICE = "device"

_DEVICE_ROOTS = {"jnp", "jax", "lax"}
_NUMPY_ROOTS = {"np", "numpy"}
# module-level helpers whose results live on device (greedy() is the
# engine's argmax-on-device sampler)
_DEVICE_FNS = {"greedy"}
_SCALAR_CASTS = {"int", "float", "bool"}


def _chain(node: ast.AST) -> tuple[str, ...] | None:
    """Dotted name chain for Name/Attribute expressions, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    """Qualified name ('Class.method' or 'func') -> def node."""
    out: dict[str, ast.FunctionDef] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out[f"{node.name}.{sub.name}"] = sub
    return out


def _parse_entry(entry: str) -> tuple[str, str]:
    """Split a 'path::Symbol' config entry."""
    path, _, symbol = entry.partition("::")
    return path, symbol


def _own_scope(fn) -> list[ast.stmt]:
    """Statements of fn excluding nested function/class bodies."""
    out: list[ast.stmt] = []
    stack = list(fn.body)
    while stack:
        st = stack.pop()
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            continue
        out.append(st)
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.stmt):
                stack.append(child)
            elif hasattr(child, "body") and isinstance(
                    getattr(child, "body", None), list):
                stack.extend(s for s in child.body
                             if isinstance(s, ast.stmt))
    return out


# ---------------------------------------------------------------------------
# PL001 — host sync in hot path
# ---------------------------------------------------------------------------

def check_host_sync(tree, source, relpath, cfg: Config, ann: Annotations,
                    ) -> list[Violation]:
    entries = [sym for (path, sym) in map(_parse_entry, cfg.hot_path)
               if path == relpath]
    if not entries:
        return []
    funcs = _functions(tree)

    # transitive closure of self./module-level calls from the entry points
    def callees(qual: str) -> set[str]:
        fn = funcs.get(qual)
        if fn is None:
            return set()
        cls = qual.rsplit(".", 1)[0] if "." in qual else None
        out: set[str] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _chain(node.func)
            if chain is None:
                continue
            if chain[0] == "self" and len(chain) == 2 and cls:
                target = f"{cls}.{chain[1]}"
                if target in funcs:
                    out.add(target)
            elif len(chain) == 1 and chain[0] in funcs:
                out.add(chain[0])
        return out

    hot: set[str] = set()
    frontier = [e for e in entries if e in funcs]
    missing = [e for e in entries if e not in funcs]
    violations = [
        Violation("PL001", relpath, 1,
                  f"configured hot-path entry {e!r} not found in file "
                  "(stale [tool.papilint] hot_path?)")
        for e in missing]
    while frontier:
        qual = frontier.pop()
        if qual in hot:
            continue
        hot.add(qual)
        frontier.extend(callees(qual) - hot)

    for qual in sorted(hot):
        violations.extend(_scan_hot_function(funcs[qual], qual, relpath,
                                             cfg, ann))
    return violations


def _scan_hot_function(fn, qual, relpath, cfg: Config, ann: Annotations,
                       ) -> list[Violation]:
    env: dict[str, str | None] = {}
    violations: list[Violation] = []

    def taint(expr) -> str | None:
        if isinstance(expr, ast.Name):
            return env.get(expr.id)
        if isinstance(expr, ast.Call):
            chain = _chain(expr.func)
            if chain is None:
                return None
            if chain[0] == "self" and len(chain) == 2:
                if chain[1] in cfg.transfer_wrappers:
                    return HOST
                if chain[1] == cfg.dispatch_fn:
                    return DEVICE
                return None
            if chain[0] in _NUMPY_ROOTS:
                return HOST
            if chain[0] in _DEVICE_ROOTS:
                return HOST if chain[-1] == "device_get" else DEVICE
            if len(chain) == 1:
                if chain[0] in _SCALAR_CASTS or chain[0] == "len":
                    return HOST
                if chain[0] in _DEVICE_FNS:
                    return DEVICE
            return None
        if isinstance(expr, ast.Attribute):
            chain = _chain(expr)
            if chain and chain[0] == "self" and len(chain) >= 2 \
                    and chain[1] in cfg.host_state_attrs:
                return HOST
            return None
        if isinstance(expr, ast.Subscript):
            return taint(expr.value)
        if isinstance(expr, (ast.BinOp, ast.Compare, ast.BoolOp,
                             ast.UnaryOp, ast.IfExp)):
            subs = [taint(s) for s in ast.iter_child_nodes(expr)
                    if isinstance(s, ast.expr)]
            if DEVICE in subs:
                return DEVICE
            if HOST in subs:
                return HOST
            return None
        if isinstance(expr, (ast.Constant, ast.List, ast.ListComp,
                             ast.Dict, ast.Set)):
            return HOST
        return None

    def flag(call: ast.Call, what: str) -> None:
        if ann.transfer_allowed(call):
            return
        violations.append(Violation(
            "PL001", relpath, call.lineno,
            f"{what} in hot-path function {qual!r} — add a "
            "papilint allow-transfer(<reason>) comment if sanctioned"))

    def check_call(call: ast.Call) -> None:
        chain = _chain(call.func)
        if isinstance(call.func, ast.Attribute):
            if call.func.attr == "item" and not call.args:
                flag(call, "host sync: .item() pulls a scalar off device")
                return
            if call.func.attr == "block_until_ready":
                flag(call, "host sync: block_until_ready blocks on device "
                           "work")
                return
        if chain is None:
            return
        if chain[0] in _DEVICE_ROOTS and chain[-1] == "device_get":
            flag(call, "host sync: jax.device_get copies device->host")
            return
        if chain[0] == "self" and len(chain) == 2 \
                and chain[1] in cfg.transfer_wrappers:
            flag(call, f"sanctioned transfer wrapper self.{chain[1]}()")
            return
        if len(chain) == 1 and chain[0] in _SCALAR_CASTS and call.args:
            if taint(call.args[0]) == DEVICE:
                flag(call, f"implicit host sync: {chain[0]}() on a device "
                           "value")
            return
        if chain[0] in _NUMPY_ROOTS and chain[-1] in ("asarray", "array") \
                and call.args:
            if taint(call.args[0]) == DEVICE:
                flag(call, f"implicit host sync: {'.'.join(chain)} on a "
                           "device value")

    def check_expr(expr) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                check_call(node)

    def bind(target, t) -> None:
        if isinstance(target, ast.Name):
            env[target.id] = t
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                bind(e, t)

    # statement-ordered scan so taint assignments precede later reads;
    # compound statements check their header expressions then recurse
    def visit_block(stmts) -> None:
        for st in stmts:
            if isinstance(st, ast.Assign):
                check_expr(st.value)
                for tgt in st.targets:
                    bind(tgt, taint(st.value))
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                check_expr(st.value)
                bind(st.target, taint(st.value))
            elif isinstance(st, ast.AugAssign):
                check_expr(st.value)
            elif isinstance(st, (ast.For, ast.AsyncFor)):
                check_expr(st.iter)
                visit_block(st.body)
                visit_block(st.orelse)
            elif isinstance(st, (ast.While, ast.If)):
                check_expr(st.test)
                visit_block(st.body)
                visit_block(st.orelse)
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    check_expr(item.context_expr)
                visit_block(st.body)
            elif isinstance(st, ast.Try):
                visit_block(st.body)
                for h in st.handlers:
                    visit_block(h.body)
                visit_block(st.orelse)
                visit_block(st.finalbody)
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                visit_block(st.body)  # nested closures are still hot
            else:
                # Expr / Return / Assert / Raise / Delete / ...
                check_expr(st)

    visit_block(fn.body)
    return violations


# ---------------------------------------------------------------------------
# PL002 — dispatch discipline
# ---------------------------------------------------------------------------

def check_dispatch(tree, source, relpath, cfg: Config, ann: Annotations,
                   ) -> list[Violation]:
    if relpath not in cfg.engine_files:
        return []
    violations: list[Violation] = []
    funcs = _functions(tree)
    for qual, fn in funcs.items():
        if fn.name.startswith(cfg.getter_prefix):
            # only the getter's own returns: the nested jitted closures it
            # builds return device pytrees, not (key, fn) pairs
            for node in _own_scope(fn):
                if isinstance(node, ast.Return) and node.value is not None:
                    ok = (isinstance(node.value, ast.Tuple)
                          and len(node.value.elts) == 2)
                    if not ok and not ann.disabled("PL002", node):
                        violations.append(Violation(
                            "PL002", relpath, node.lineno,
                            f"program getter {qual!r} must return a "
                            "(key, fn) 2-tuple so dispatch can route "
                            "through self._call"))
        # bare dispatch of a getter-returned fn
        fn_vars: dict[str, str] = {}   # fn var -> getter name
        key_of: dict[str, str] = {}    # fn var -> key var
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                continue
            chain = _chain(node.value.func)
            if not (chain and chain[0] == "self" and len(chain) == 2
                    and chain[1].startswith(cfg.getter_prefix)):
                continue
            if len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Tuple) \
                    and len(node.targets[0].elts) == 2 \
                    and all(isinstance(e, ast.Name)
                            for e in node.targets[0].elts):
                k, f = node.targets[0].elts
                fn_vars[f.id] = chain[1]
                key_of[f.id] = k.id
        if not fn_vars:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) \
                    and node.func.id in fn_vars:
                if not ann.disabled("PL002", node):
                    violations.append(Violation(
                        "PL002", relpath, node.lineno,
                        f"bare dispatch of {fn_vars[node.func.id]!r} "
                        f"program ({node.func.id}(...)) — route through "
                        f"self.{cfg.dispatch_fn}(key, fn, ...) so the "
                        "tracer times it"))
                continue
            chain = _chain(node.func)
            if chain and chain[0] == "self" and len(chain) == 2 \
                    and chain[1] == cfg.dispatch_fn and len(node.args) >= 2:
                key_arg, fn_arg = node.args[0], node.args[1]
                if isinstance(fn_arg, ast.Name) \
                        and fn_arg.id in key_of \
                        and isinstance(key_arg, ast.Name) \
                        and key_arg.id != key_of[fn_arg.id] \
                        and not ann.disabled("PL002", node):
                    violations.append(Violation(
                        "PL002", relpath, node.lineno,
                        f"program {fn_arg.id!r} dispatched under key "
                        f"{key_arg.id!r} but its getter returned key "
                        f"{key_of[fn_arg.id]!r} — timings would be "
                        "misattributed"))
    # calling straight out of a jit cache bypasses _call as well
    for qual, fn in funcs.items():
        if fn.name.startswith(cfg.getter_prefix) \
                or fn.name == cfg.dispatch_fn:
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Subscript):
                chain = _chain(node.func.value)
                if chain and chain[0] == "self" \
                        and chain[-1].endswith("_jit") \
                        and not ann.disabled("PL002", node):
                    violations.append(Violation(
                        "PL002", relpath, node.lineno,
                        f"direct call into jit cache "
                        f"self.{'.'.join(chain[1:])} in {qual!r} — "
                        "fetch (key, fn) from a getter and route through "
                        f"self.{cfg.dispatch_fn}"))
    return violations


# ---------------------------------------------------------------------------
# PL003 — jit-cache-key completeness
# ---------------------------------------------------------------------------

def _self_paths(node) -> set[str]:
    """All dotted self.* attribute chains read anywhere under node."""
    out: set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute):
            chain = _chain(sub)
            if chain and chain[0] == "self" and len(chain) > 1:
                out.add(".".join(chain[1:]))
    return out


def check_jit_keys(tree, source, relpath, cfg: Config, ann: Annotations,
                   ) -> list[Violation]:
    if relpath not in cfg.engine_files:
        return []
    violations: list[Violation] = []
    funcs = _functions(tree)

    # atoms contributed by the canonical key builder (_jit_key)
    builder_atoms: set[str] = set()
    for qual, fn in funcs.items():
        if fn.name == cfg.jit_key_builder:
            for node in ast.walk(fn):
                if isinstance(node, ast.Return) and node.value is not None:
                    builder_atoms |= _self_paths(node.value)

    def is_getter(fn) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                chain = _chain(node.func)
                if chain and chain[-1] == "jit" \
                        and chain[0] in _DEVICE_ROOTS:
                    return True
        return False

    for qual, fn in funcs.items():
        if not is_getter(fn):
            continue
        # locate the cache-key expression: `key = ...`, else the first
        # element of a returned 2-tuple
        key_expr = None
        for st in ast.walk(fn):
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name) \
                    and st.targets[0].id == "key":
                key_expr = st.value
                break
        if key_expr is None:
            for st in ast.walk(fn):
                if isinstance(st, ast.Return) \
                        and isinstance(st.value, ast.Tuple) \
                        and len(st.value.elts) == 2:
                    key_expr = st.value.elts[0]
                    break
        if key_expr is None:
            continue

        # collect key atoms, resolving local names one assignment deep
        atoms: set[str] = set()
        builder_used = False
        seen: set[str] = set()

        def collect(expr) -> None:
            nonlocal builder_used
            for node in ast.walk(expr):
                if isinstance(node, ast.Attribute):
                    chain = _chain(node)
                    if chain and chain[0] == "self" and len(chain) > 1:
                        atoms.add(".".join(chain[1:]))
                elif isinstance(node, ast.Call):
                    chain = _chain(node.func)
                    if chain is None:
                        continue
                    if chain[0] == "self" and len(chain) == 2 \
                            and chain[1] == cfg.jit_key_builder:
                        builder_used = True
                    atoms.add(chain[-1])
                elif isinstance(node, ast.Name) and node.id not in seen:
                    seen.add(node.id)
                    for st in ast.walk(fn):
                        if isinstance(st, ast.Assign) \
                                and len(st.targets) == 1 \
                                and isinstance(st.targets[0], ast.Name) \
                                and st.targets[0].id == node.id:
                            collect(st.value)
                            break

        collect(key_expr)
        if builder_used:
            atoms |= builder_atoms

        reads = _self_paths(fn)
        for flag in list(cfg.jit_key_flags) + list(cfg.jit_key_attr_paths):
            if flag in reads and flag not in atoms \
                    and not ann.disabled("PL003", key_expr) \
                    and not ann.disabled("PL003", fn):
                violations.append(Violation(
                    "PL003", relpath, key_expr.lineno,
                    f"jitted program getter {qual!r} reads self.{flag} "
                    "but its jit-cache key does not include it — a "
                    "runtime flip would silently reuse the stale "
                    "compiled program"))
        if not builder_used \
                and not (set(cfg.ambient_key_reads) & atoms) \
                and not ann.disabled("PL003", key_expr) \
                and not ann.disabled("PL003", fn):
            violations.append(Violation(
                "PL003", relpath, key_expr.lineno,
                f"jit-cache key in {qual!r} is not derived from "
                f"self.{cfg.jit_key_builder}() and captures none of "
                f"{sorted(cfg.ambient_key_reads)} — the seed bug: a key "
                "blind to the ambient FC variant bakes in whichever "
                "variant traced first"))
    return violations


# ---------------------------------------------------------------------------
# PL004 — Pallas kernel contracts
# ---------------------------------------------------------------------------

def _resolve_int(expr, fn) -> int | None:
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
        return expr.value
    return None


def _resolve_tuple_len(expr, fn) -> int | None:
    if isinstance(expr, ast.Tuple):
        return len(expr.elts)
    if isinstance(expr, ast.Name):
        for st in ast.walk(fn):
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name) \
                    and st.targets[0].id == expr.id \
                    and isinstance(st.value, ast.Tuple):
                return len(st.value.elts)
    return None


def _kw(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _index_map_params(expr, fn, module) -> tuple[int, ast.AST] | None:
    """(param count, body node) for a lambda or locally-defined index map."""
    if isinstance(expr, ast.Lambda):
        return len(expr.args.args), expr.body
    if isinstance(expr, ast.Name):
        for scope in (fn, module):
            for st in ast.walk(scope):
                if isinstance(st, ast.FunctionDef) and st.name == expr.id:
                    return len(st.args.args), st
    return None


def _resolve_kernel(expr, fn, module) -> ast.FunctionDef | None:
    if isinstance(expr, ast.Call):  # functools.partial(kernel, ...)
        chain = _chain(expr.func)
        if chain and chain[-1] == "partial" and expr.args:
            expr = expr.args[0]
    if isinstance(expr, ast.Name):
        for scope in (fn, module):
            for st in ast.walk(scope):
                if isinstance(st, ast.FunctionDef) and st.name == expr.id:
                    return st
    return None


def check_pallas(tree, source, relpath, cfg: Config, ann: Annotations,
                 ) -> list[Violation]:
    if "BlockSpec" not in source:
        return []
    violations: list[Violation] = []
    module_fns = [n for n in tree.body if isinstance(n, ast.FunctionDef)]
    for fn in module_fns:
        pcalls = [n for n in ast.walk(fn)
                  if isinstance(n, ast.Call)
                  and isinstance(n.func, ast.Attribute)
                  and n.func.attr == "pallas_call"]
        if not pcalls:
            continue
        pcall = pcalls[0]
        spec_calls = [n for n in ast.walk(fn)
                      if isinstance(n, ast.Call)
                      and isinstance(n.func, ast.Attribute)
                      and n.func.attr == "PrefetchScalarGridSpec"]
        if spec_calls:
            grid_owner = spec_calls[0]
            prefetch = _resolve_int(_kw(grid_owner, "num_scalar_prefetch"),
                                    fn) or 0
        else:
            grid_owner = pcall
            prefetch = 0
        grid_expr = _kw(grid_owner, "grid")
        rank = _resolve_tuple_len(grid_expr, fn) \
            if grid_expr is not None else None

        # index_map arity
        block_specs = [n for n in ast.walk(fn)
                       if isinstance(n, ast.Call)
                       and isinstance(n.func, ast.Attribute)
                       and n.func.attr == "BlockSpec"]
        clamped_maps: list[tuple[ast.AST, ast.AST]] = []
        for bs in block_specs:
            imap = bs.args[1] if len(bs.args) >= 2 else _kw(bs, "index_map")
            if imap is None:
                continue
            resolved = _index_map_params(imap, fn, tree)
            if resolved is None:
                continue
            nparams, body = resolved
            if rank is not None:
                expected = rank + prefetch
                if nparams != expected and not ann.disabled("PL004", bs):
                    violations.append(Violation(
                        "PL004", relpath, bs.lineno,
                        f"BlockSpec index_map in {fn.name!r} takes "
                        f"{nparams} parameter(s) but the grid spec "
                        f"provides {expected} (grid rank {rank} + "
                        f"{prefetch} scalar-prefetch ref(s))"))
            for node in ast.walk(body):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in ("minimum", "clip"):
                    clamped_maps.append((bs, body))
                    break

        # operand / kernel parameter counts
        in_specs = _kw(grid_owner, "in_specs")
        n_in = len(in_specs.elts) if isinstance(in_specs, ast.List) else None
        out_specs = _kw(grid_owner, "out_specs")
        n_out = len(out_specs.elts) if isinstance(out_specs, ast.List) \
            else (1 if out_specs is not None else None)
        scratch = _kw(grid_owner, "scratch_shapes")
        n_scratch = len(scratch.elts) if isinstance(scratch, ast.List) else 0

        if spec_calls and n_in is not None:
            # the pallas_call result is invoked with (scalars..., operands...)
            outer = [n for n in ast.walk(fn)
                     if isinstance(n, ast.Call) and n.func is pcall]
            for call in outer:
                got = len(call.args)
                want = prefetch + n_in
                if got != want and not ann.disabled("PL004", call):
                    violations.append(Violation(
                        "PL004", relpath, call.lineno,
                        f"pallas_call in {fn.name!r} invoked with {got} "
                        f"operand(s) but the grid spec expects {want} "
                        f"({prefetch} scalar-prefetch + {n_in} in_specs)"))

        kernel = _resolve_kernel(pcall.args[0] if pcall.args else None,
                                 fn, tree)
        if kernel is not None and n_in is not None and n_out is not None:
            nparams = len(kernel.args.posonlyargs) + len(kernel.args.args)
            expected = prefetch + n_in + n_out + n_scratch
            if nparams != expected and not ann.disabled("PL004", kernel):
                violations.append(Violation(
                    "PL004", relpath, kernel.lineno,
                    f"kernel {kernel.name!r} takes {nparams} positional "
                    f"ref(s) but the grid spec supplies {expected} "
                    f"({prefetch} scalar-prefetch + {n_in} inputs + "
                    f"{n_out} outputs + {n_scratch} scratch)"))
        if clamped_maps and kernel is not None:
            guarded = any(
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "when"
                for node in ast.walk(kernel))
            if not guarded:
                bs, _ = clamped_maps[0]
                if not ann.disabled("PL004", bs):
                    violations.append(Violation(
                        "PL004", relpath, bs.lineno,
                        f"index_map in {fn.name!r} clamps its block index "
                        "(ragged tail) but kernel "
                        f"{kernel.name!r} has no pl.when guard — the "
                        "re-fetched tail block would be accumulated "
                        "twice"))
    return violations


# ---------------------------------------------------------------------------
# PL005 — mirror / exporter / CLI drift (cross-file)
# ---------------------------------------------------------------------------

def _module_str_set(root: Path, entry: str,
                    ) -> tuple[set[str] | None, int, str]:
    """String constants inside module-level assignment `SYM = ...`."""
    path, symbol = _parse_entry(entry)
    file = root / path
    if not file.exists():
        return None, 1, f"{path} does not exist"
    tree = ast.parse(file.read_text(), filename=str(file))
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
        else:
            continue
        names = [t.id for t in targets if isinstance(t, ast.Name)]
        if symbol in names:
            strs = {n.value for n in ast.walk(node.value)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, str)}
            return strs, node.lineno, ""
    return None, 1, f"{path} has no module-level assignment to {symbol}"


def check_mirrors(cfg: Config, root: Path) -> list[Violation]:
    violations: list[Violation] = []
    for mirror in cfg.mirrors:
        left, _, right = mirror.partition("=")
        lset, lline, lerr = _module_str_set(root, left)
        rset, rline, rerr = _module_str_set(root, right)
        lpath, lsym = _parse_entry(left)
        rpath, rsym = _parse_entry(right)
        if lset is None or rset is None:
            violations.append(Violation(
                "PL005", lpath if lset is None else rpath, 1,
                f"mirror check failed: {lerr or rerr}"))
            continue
        if lset != rset:
            only_l = sorted(lset - rset)
            only_r = sorted(rset - lset)
            detail = []
            if only_l:
                detail.append(f"only in {lpath}::{lsym}: {only_l}")
            if only_r:
                detail.append(f"only in {rpath}::{rsym}: {only_r}")
            violations.append(Violation(
                "PL005", rpath, rline,
                f"mirror drift between {lpath}::{lsym} and "
                f"{rpath}::{rsym} — " + "; ".join(detail)))
    return violations


def check_exporters(cfg: Config, root: Path) -> list[Violation]:
    if not cfg.event_kinds_source or not cfg.exporters:
        return []
    kinds, _, err = _module_str_set(root, cfg.event_kinds_source)
    if kinds is None:
        return [Violation("PL005",
                          _parse_entry(cfg.event_kinds_source)[0], 1,
                          f"event-kind source unreadable: {err}")]
    violations: list[Violation] = []
    for entry in cfg.exporters:
        path, func_name = _parse_entry(entry)
        file = root / path
        if not file.exists():
            violations.append(Violation("PL005", path, 1,
                                        "exporter file missing"))
            continue
        tree = ast.parse(file.read_text(), filename=str(file))
        fn = _functions(tree).get(func_name)
        if fn is None:
            violations.append(Violation(
                "PL005", path, 1,
                f"configured exporter {func_name!r} not found"))
            continue
        mentioned = {n.value for n in ast.walk(fn)
                     if isinstance(n, ast.Constant)
                     and isinstance(n.value, str)}
        missing = sorted(kinds - mentioned)
        if missing:
            violations.append(Violation(
                "PL005", path, fn.lineno,
                f"exporter {func_name!r} does not handle event kind(s) "
                f"{missing} — events of those kinds would silently "
                "vanish from the export"))
    return violations


def check_cli_docs(cfg: Config, root: Path) -> list[Violation]:
    violations: list[Violation] = []
    for entry in cfg.cli_docs:
        cli_path, _, docs_spec = entry.partition("=")
        doc_paths = [d for d in docs_spec.split(",") if d]
        cli_file = root / cli_path
        if not cli_file.exists():
            violations.append(Violation("PL005", cli_path, 1,
                                        "configured CLI file missing"))
            continue
        docs_text = ""
        for doc in doc_paths:
            doc_file = root / doc
            if not doc_file.exists():
                violations.append(Violation(
                    "PL005", cli_path, 1,
                    f"configured doc {doc!r} missing"))
            else:
                docs_text += doc_file.read_text()
        # the CLI module's own docstring counts as documentation of last
        # resort only if listed explicitly — flags must live in real docs
        tree = ast.parse(cli_file.read_text(), filename=str(cli_file))
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "add_argument"
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            flag = node.args[0].value
            if not flag.startswith("--"):
                continue
            if flag not in docs_text:
                violations.append(Violation(
                    "PL005", cli_path, node.lineno,
                    f"CLI flag {flag!r} is not mentioned in any of "
                    f"{doc_paths} — undocumented surface area"))
    return violations
