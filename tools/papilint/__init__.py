"""papilint: repo-specific static analysis for the PAPI serving engine.

The engine's performance claims rest on invariants no general-purpose
linter knows about: one host transfer per fused iteration, jit caches
keyed on every scheduler-visible flag, every dispatch routed through the
telemetry ``_call`` path, and Pallas grid specs whose index maps agree
with their grids.  papilint checks those invariants at the AST level so
regressions are caught before a test ever runs.

Checkers
--------
PL001  host-sync-in-hot-path: device syncs (`.item()`, ``jax.device_get``,
       ``block_until_ready``, ``int()``/``float()``/``bool()`` or
       ``np.asarray`` on device values) inside the engine's hot path must
       carry a ``# papilint: allow-transfer(<reason>)`` annotation.
PL002  dispatch discipline: ``_get_*`` program getters return
       ``(key, fn)`` and dispatch routes through ``PapiEngine._call``,
       never a bare ``fn(...)``.
PL003  jit-cache-key completeness: mutable ``self.<flag>`` reads inside a
       jitted-program getter must appear in its jit-cache key, and keys
       not derived from ``_jit_key`` must capture the ambient FC variant
       (the seed's original bug).
PL004  Pallas kernel contracts: BlockSpec ``index_map`` arity matches
       grid rank (+ scalar prefetch), operand counts match the grid
       spec, ragged clamps are guarded by ``pl.when``.
PL005  mirror/CLI drift: ``EVENT_KINDS`` mirrors stay equal, exporters
       cover every event kind, argparse flags are documented.

Run ``python -m tools.papilint src tools benchmarks`` from the repo root.
Configuration lives in ``[tool.papilint]`` in pyproject.toml.
"""
from tools.papilint.config import Config, load_config  # noqa: F401
from tools.papilint.core import Violation, run_paths  # noqa: F401
