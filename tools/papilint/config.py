"""papilint configuration: the ``[tool.papilint]`` block in pyproject.toml.

Python 3.10 has no ``tomllib``, and papilint must stay stdlib-only (it
runs in CI before any dependency install), so this module parses the
narrow TOML subset the config actually uses: a single table of
``key = "string"`` / ``key = ["string", ...]`` entries, with arrays
allowed to span lines.  Anything outside that subset is a hard error —
better a loud parse failure than a silently ignored checker.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

SECTION = "[tool.papilint]"


@dataclasses.dataclass
class Config:
    """Resolved papilint configuration (all paths repo-relative, POSIX)."""

    # PL001 — entry points whose transitive self-call closure is the hot
    # path, as "path::Qualified.name" entries.
    hot_path: list[str] = dataclasses.field(default_factory=list)
    # PL001 — methods that ARE the sanctioned device->host sync wrapper;
    # calls to them are flagged and must carry an allow-transfer reason.
    transfer_wrappers: list[str] = dataclasses.field(default_factory=list)
    # PL001 — engine attributes known to hold host (numpy/python) state:
    # int()/float()/np.asarray on them is bookkeeping, not a device sync.
    host_state_attrs: list[str] = dataclasses.field(default_factory=list)
    # PL002/PL003 — files holding the dispatch layer.
    engine_files: list[str] = dataclasses.field(default_factory=list)
    dispatch_fn: str = "_call"
    getter_prefix: str = "_get_"
    # PL003 — mutable flags that must appear in every jit-cache key that
    # reads them, plus dotted attribute paths treated the same way.
    jit_key_flags: list[str] = dataclasses.field(default_factory=list)
    jit_key_attr_paths: list[str] = dataclasses.field(default_factory=list)
    # PL003 — ambient (thread-local) reads a non-_jit_key-derived key must
    # capture, and the name of the canonical key builder.
    ambient_key_reads: list[str] = dataclasses.field(default_factory=list)
    jit_key_builder: str = "_jit_key"
    # PL005 — "fileA::SYM=fileB::SYM" literal-equality mirrors.
    mirrors: list[str] = dataclasses.field(default_factory=list)
    # PL005 — canonical event-kind set ("file::SYM") and the exporter
    # functions ("file::func") whose bodies must mention every kind.
    event_kinds_source: str = ""
    exporters: list[str] = dataclasses.field(default_factory=list)
    # PL005 — "cli_file=doc1|doc2": every --flag defined in cli_file must
    # be mentioned in at least one of the listed docs.
    cli_docs: list[str] = dataclasses.field(default_factory=list)


class ConfigError(ValueError):
    pass


_KEY_RE = re.compile(r"^([A-Za-z0-9_-]+)\s*=\s*(.*)$")


def _parse_value(text: str, key: str):
    """Parse a TOML string / string-or-int array via ast.literal_eval.

    Valid for our subset because TOML double-quoted strings and
    ``[ ... ]`` arrays of them are also Python literals.
    """
    try:
        value = ast.literal_eval(text)
    except (ValueError, SyntaxError) as exc:
        raise ConfigError(
            f"{SECTION} key {key!r}: unsupported TOML value {text!r} "
            "(papilint reads only strings and arrays of strings)") from exc
    if isinstance(value, tuple):
        value = list(value)
    if not (isinstance(value, str)
            or (isinstance(value, list)
                and all(isinstance(v, str) for v in value))):
        raise ConfigError(
            f"{SECTION} key {key!r}: expected a string or array of "
            f"strings, got {value!r}")
    return value


def parse_pyproject(text: str) -> dict:
    """Extract the raw [tool.papilint] table from pyproject.toml text."""
    lines = text.splitlines()
    try:
        start = next(i for i, ln in enumerate(lines)
                     if ln.strip() == SECTION)
    except StopIteration:
        raise ConfigError(
            f"pyproject.toml has no {SECTION} section — papilint is "
            "unconfigured") from None
    raw: dict = {}
    i = start + 1
    while i < len(lines):
        line = lines[i].strip()
        if line.startswith("["):  # next table
            break
        if not line or line.startswith("#"):
            i += 1
            continue
        m = _KEY_RE.match(line)
        if m is None:
            raise ConfigError(f"{SECTION}: cannot parse line {i + 1}: "
                              f"{line!r}")
        key, value_text = m.group(1), m.group(2)
        # arrays may span lines: accumulate until brackets balance
        while value_text.count("[") > value_text.count("]"):
            i += 1
            if i >= len(lines):
                raise ConfigError(f"{SECTION} key {key!r}: unterminated "
                                  "array")
            value_text += " " + lines[i].strip()
        # strip trailing comments outside strings (our subset: a '#' that
        # follows the closing bracket/quote)
        raw[key] = _parse_value(value_text, key)
        i += 1
    return raw


def load_config(pyproject: Path) -> Config:
    raw = parse_pyproject(pyproject.read_text())
    fields = {f.name: f for f in dataclasses.fields(Config)}
    kwargs = {}
    for key, value in raw.items():
        name = key.replace("-", "_")
        if name not in fields:
            raise ConfigError(f"{SECTION}: unknown key {key!r}")
        kwargs[name] = value
    return Config(**kwargs)
