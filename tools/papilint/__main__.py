"""CLI: ``python -m tools.papilint [paths...]`` from the repo root.

Exits 0 when the tree is clean, 1 when any violation (or malformed
annotation) is found.  Paths default to the CI surface: src, tools,
benchmarks.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.papilint.config import ConfigError, load_config
from tools.papilint.core import run_paths

REPO_ROOT = Path(__file__).resolve().parent.parent.parent


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="papilint",
        description="repo-specific static analysis for the PAPI engine "
                    "(PL001 host-sync, PL002 dispatch, PL003 jit keys, "
                    "PL004 Pallas contracts, PL005 mirror/CLI drift)")
    parser.add_argument("paths", nargs="*",
                        default=["src", "tools", "benchmarks"],
                        help="files or directories to lint "
                             "(default: src tools benchmarks)")
    parser.add_argument("--root", type=Path, default=REPO_ROOT,
                        help="repo root for config + relative paths")
    parser.add_argument("--config", type=Path, default=None,
                        help="pyproject.toml holding [tool.papilint] "
                             "(default: <root>/pyproject.toml)")
    args = parser.parse_args(argv)

    pyproject = args.config or (args.root / "pyproject.toml")
    try:
        config = load_config(pyproject)
    except (ConfigError, OSError) as exc:
        print(f"papilint: {exc}", file=sys.stderr)
        return 1

    violations = run_paths([Path(p) for p in args.paths], config,
                           args.root)
    for v in violations:
        print(v.render())
    if violations:
        print(f"papilint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("papilint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
