"""papilint core: violations, annotation parsing, and the file walker."""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

from tools.papilint.config import Config


@dataclasses.dataclass(frozen=True)
class Violation:
    code: str       # "PL001".."PL005" (PL000 = malformed annotation)
    path: str       # repo-relative POSIX path
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


# --- annotation grammar ----------------------------------------------------
#
#   ``papilint: allow-transfer(<reason>)`` comment  sanctions a PL001 site
#   ``papilint: disable=PL00N (<reason>)`` comment  suppresses one finding
#
# An annotation applies to the statement it trails OR the statement on the
# next line (own-line comment above a call).  The reason is mandatory: a
# sanctioned sync without a recorded why is itself a violation.

_ALLOW_RE = re.compile(r"#\s*papilint:\s*allow-transfer\(([^)]*)\)")
_DISABLE_RE = re.compile(
    r"#\s*papilint:\s*disable=(PL\d{3})\s*(?:\(([^)]*)\))?")
_ANY_RE = re.compile(r"#\s*papilint:")


class Annotations:
    """Per-file papilint annotations, keyed by source line."""

    def __init__(self, source: str, relpath: str):
        self.relpath = relpath
        self.allow_transfer: dict[int, str] = {}
        self.disable: dict[int, tuple[str, str]] = {}
        self.malformed: list[Violation] = []
        for lineno, text in enumerate(source.splitlines(), start=1):
            m = _ALLOW_RE.search(text)
            if m:
                reason = m.group(1).strip()
                if not reason:
                    self.malformed.append(Violation(
                        "PL000", relpath, lineno,
                        "allow-transfer annotation needs a reason in "
                        "parentheses: why is this sync sanctioned?"))
                else:
                    self.allow_transfer[lineno] = reason
                continue
            m = _DISABLE_RE.search(text)
            if m:
                code, reason = m.group(1), (m.group(2) or "").strip()
                if not reason:
                    self.malformed.append(Violation(
                        "PL000", relpath, lineno,
                        f"disable={code} annotation needs a reason in "
                        "parentheses: why is this finding suppressed?"))
                else:
                    self.disable[lineno] = (code, reason)
                continue
            if _ANY_RE.search(text):
                self.malformed.append(Violation(
                    "PL000", relpath, lineno,
                    "unrecognized papilint annotation (grammar: "
                    "allow-transfer(<reason>) or disable=PL00N (<reason>))"))

    @staticmethod
    def _covers(lines: dict, node: ast.AST) -> bool:
        lo = node.lineno - 1  # own-line comment directly above
        hi = getattr(node, "end_lineno", node.lineno)
        return any(lo <= ln <= hi for ln in lines)

    def transfer_allowed(self, node: ast.AST) -> bool:
        return self._covers(self.allow_transfer, node)

    def disabled(self, code: str, node: ast.AST) -> bool:
        lines = {ln: None for ln, (c, _) in self.disable.items()
                 if c == code}
        return self._covers(lines, node)


def run_paths(paths: list[Path], config: Config, root: Path,
              ) -> list[Violation]:
    """Lint every .py file under the given paths (files or directories)."""
    from tools.papilint import checkers

    files: list[Path] = []
    for p in paths:
        p = p if p.is_absolute() else root / p
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    violations: list[Violation] = []
    for path in files:
        relpath = path.relative_to(root).as_posix() \
            if path.is_relative_to(root) else path.as_posix()
        source = path.read_text()
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as exc:
            violations.append(Violation(
                "PL000", relpath, exc.lineno or 1,
                f"file does not parse: {exc.msg}"))
            continue
        ann = Annotations(source, relpath)
        violations.extend(ann.malformed)
        for check in (checkers.check_host_sync, checkers.check_dispatch,
                      checkers.check_jit_keys, checkers.check_pallas):
            violations.extend(check(tree, source, relpath, config, ann))
    # repo-level (cross-file) checks
    violations.extend(checkers.check_mirrors(config, root))
    violations.extend(checkers.check_exporters(config, root))
    violations.extend(checkers.check_cli_docs(config, root))
    violations.sort(key=lambda v: (v.path, v.line, v.code))
    return violations
