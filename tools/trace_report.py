"""Summarize (or validate) a PAPI engine trace.

    python tools/trace_report.py out.trace.json            # human summary
    python tools/trace_report.py out.trace.json --validate # CI schema gate

Reads either trace serialization `repro.serving.telemetry.write_trace`
produces — Chrome-trace-event JSON (autodetected by its ``traceEvents``
key; the typed payload rides in each event's ``args`` and the aggregate
tables under the top-level ``"papi"`` key) or raw JSONL (one typed event
per line plus a trailing ``summary`` record) — and prints:

  * the per-compiled-program timing table by jit-cache key (count / mean /
    min / max / total wall seconds around `block_until_ready`) — the table
    a measured-characterization scheduler consumes;
  * the scheduler flip timeline: every pu<->pim reschedule with the AI
    estimate and the alpha threshold that flipped it;
  * page-pool occupancy: high-water mark and the peak sampled used/free;
  * per-request span summaries: queue (submit->admit) -> prefill
    (admit->first token) -> decode (first token->finish), with the finish
    reason and token count.

``--validate`` (used by CI) checks the schema instead: every event kind
must be in the vocabulary, the aggregate tables must be well-formed, and
the trace must contain a nonzero number of scheduler decisions and
iteration spans — exit 1 with a message otherwise.

Deliberately stdlib-only (no jax, no repro imports): the report must run
anywhere a trace file lands, so it keeps its OWN copy of the event
vocabulary, mirrored from `repro.serving.telemetry.EVENT_KINDS` (the
telemetry tests assert the two stay in sync).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# mirror of repro.serving.telemetry.EVENT_KINDS (tests assert equality)
EVENT_KINDS = frozenset({
    "submit", "admit", "first_token", "finish", "preempt", "defer",
    "scheduler", "iteration", "pool", "fault", "degraded", "program",
    "page_map", "page_unmap", "page_reserve", "stall", "journal", "recover",
})

PROGRAM_FIELDS = ("count", "total_s", "mean_s", "min_s", "max_s")


def load_trace(path: Path) -> tuple[list[dict], dict]:
    """Parse either serialization into (typed events, aggregate summary).

    Events are normalized to ``{"kind", "iteration", "ts", "dur", "data"}``
    with ts/dur in seconds; the summary dict carries ``counters``,
    ``gauges``, ``programs``, ``events_emitted``, ``events_dropped``.
    """
    text = path.read_text()
    head = text.lstrip()[:1]
    if head == "{" and '"traceEvents"' in text[:4096]:
        doc = json.loads(text)
        events = []
        for rec in doc.get("traceEvents", []):
            args = rec.get("args") or {}
            kind = args.get("kind")
            if rec.get("ph") == "C" and rec.get("name") == "kv_pages":
                # pool samples export as a Perfetto counter track whose args
                # must stay numeric-only — recover the typed event here
                events.append({"kind": "pool", "iteration": 0,
                               "ts": rec.get("ts", 0) / 1e6, "dur": 0.0,
                               "data": dict(args)})
                continue
            if rec.get("ph") == "M" or kind is None:
                continue   # lane-metadata records
            data = {k: v for k, v in args.items()
                    if k not in ("kind", "iteration")}
            events.append({"kind": kind,
                           "iteration": args.get("iteration", 0),
                           "ts": rec.get("ts", 0) / 1e6,
                           "dur": rec.get("dur", 0) / 1e6,
                           "data": data})
        return events, doc.get("papi", {})
    events, summary = [], {}
    for line in text.splitlines():
        if not line.strip():
            continue
        rec = json.loads(line)
        if rec.get("kind") == "summary":
            summary = rec.get("data", {})
        else:
            events.append(rec)
    return events, summary


def validate(events: list[dict], summary: dict) -> list[str]:
    """Schema + liveness checks for the CI gate; returns failure messages."""
    problems = []
    for i, ev in enumerate(events):
        kind = ev.get("kind")
        if kind not in EVENT_KINDS:
            problems.append(f"event {i}: unknown kind {kind!r}")
        for field in ("iteration", "ts", "dur"):
            if not isinstance(ev.get(field), (int, float)):
                problems.append(f"event {i} ({kind}): non-numeric {field}")
        if not isinstance(ev.get("data"), dict):
            problems.append(f"event {i} ({kind}): data is not an object")
        if problems and len(problems) > 20:
            problems.append("... (truncated)")
            break
    counters = summary.get("counters", {})
    programs = summary.get("programs", {})
    if not isinstance(counters, dict) or not isinstance(programs, dict):
        problems.append("summary counters/programs tables missing")
        return problems
    for key, table in programs.items():
        missing = [f for f in PROGRAM_FIELDS if f not in table]
        if missing:
            problems.append(f"program {key!r}: missing fields {missing}")
    # liveness: a trace of a real run must contain scheduler decisions and
    # iteration spans — zero of either means the engine hooks regressed.
    # Counts come from the aggregate counters (exact under ring truncation;
    # the chrome lanes only carry the FLIPPED scheduler decisions).
    n_sched = counters.get("scheduler", 0)
    n_iter = counters.get("iteration", 0)
    if n_sched <= 0:
        problems.append(f"no scheduler-decision events (counter {n_sched})")
    if n_iter <= 0:
        problems.append(f"no iteration-span events (counter {n_iter})")
    return problems


def _fmt_s(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:8.3f}s "
    return f"{seconds * 1e3:8.3f}ms"


def report(events: list[dict], summary: dict, out=None) -> None:
    out = out or sys.stdout
    w = out.write
    counters = summary.get("counters", {})
    gauges = summary.get("gauges", {})
    programs = summary.get("programs", {})

    w(f"events: {summary.get('events_emitted', len(events))} emitted, "
      f"{summary.get('events_dropped', 0)} dropped from the ring "
      f"({len(events)} in file)\n")
    w(f"iterations: {counters.get('iteration', 0)}   "
      f"tokens: {counters.get('tokens', 0)}   "
      f"degraded: {counters.get('degraded', 0)}   "
      f"preemptions: {counters.get('preempt', 0)}   "
      f"deferrals: {counters.get('defer', 0)}\n")

    # ---- per-variant program timing (the jit-cache-key table) ----
    if programs:
        w("\nprogram timing by jit-cache key "
          "(kind|tlp|fc_variant|interpret|attn_pim):\n")
        w(f"  {'key':42s} {'runs':>5s} {'mean':>10s} {'min':>10s} "
          f"{'max':>10s} {'total':>10s}\n")
        rows = sorted(programs.items(),
                      key=lambda kv: -kv[1].get("total_s", 0))
        for key, t in rows:
            w(f"  {key:42s} {t['count']:5d} {_fmt_s(t['mean_s'])} "
              f"{_fmt_s(t['min_s'])} {_fmt_s(t['max_s'])} "
              f"{_fmt_s(t['total_s'])}\n")

    # ---- scheduler flip timeline ----
    flips = [ev for ev in events
             if ev["kind"] == "scheduler" and ev["data"].get("flipped")]
    w(f"\nscheduler: {counters.get('scheduler', 0)} decisions, "
      f"{counters.get('scheduler_flip', len(flips))} flips\n")
    for ev in flips:
        d = ev["data"]
        w(f"  iter {ev['iteration']:5d}: -> {d.get('assignment', '?'):4s} "
          f"(AI {d.get('ai_estimate', 0):.1f} vs alpha "
          f"{d.get('alpha', 0):.1f}, rlp={d.get('rlp')}, "
          f"tlp={d.get('tlp')})\n")

    # ---- pool occupancy ----
    pool = [ev for ev in events if ev["kind"] == "pool"]
    if pool or any(k.startswith("kv_pages") for k in gauges):
        peak = max((ev["data"].get("used", 0) for ev in pool), default=0)
        w(f"\nkv page pool: high-water "
          f"{gauges.get('kv_pages_watermark', peak)} pages mapped "
          f"(peak sampled used {peak}, last free "
          f"{gauges.get('kv_pages_free', '?')}, fragmentation "
          f"{gauges.get('kv_pages_fragmentation', 0):.1%})\n")

    # ---- per-request spans: queue -> prefill -> decode -> finish ----
    marks: dict[int, dict] = {}
    for ev in events:
        rid = ev["data"].get("req_id")
        if rid is None or ev["kind"] not in ("submit", "admit",
                                            "first_token", "finish",
                                            "preempt"):
            continue
        m = marks.setdefault(rid, {})
        if ev["kind"] == "preempt":
            m["preempts"] = m.get("preempts", 0) + 1
        elif ev["kind"] not in m:     # first occurrence wins (preemption
            m[ev["kind"]] = ev        # re-admits through the same hooks)
        elif ev["kind"] == "finish":
            m["finish"] = ev          # ...except finish: last wins
    if marks:
        w(f"\nper-request spans ({len(marks)} requests, iterations "
          "[wall]):\n")
        w(f"  {'req':>5s} {'queue':>7s} {'prefill':>8s} {'decode':>7s} "
          f"{'total':>7s}  {'tokens':>6s}  reason\n")
        for rid in sorted(marks):
            m = marks[rid]
            sub, adm = m.get("submit"), m.get("admit")
            ft, fin = m.get("first_token"), m.get("finish")

            def span(a, b):
                if a is None or b is None:
                    return "     --"
                return f"{b['iteration'] - a['iteration']:7d}"

            toks = fin["data"].get("tokens", 0) if fin else 0
            reason = fin["data"].get("reason", "in-flight") if fin else \
                "in-flight"
            if m.get("preempts"):
                reason += f" ({m['preempts']}x preempted)"
            w(f"  {rid:5d} {span(sub, adm)} {span(adm, ft):>8s} "
              f"{span(ft, fin)} {span(sub, fin)}  {toks:6d}  {reason}\n")

    faults = {k.split(':', 1)[1]: v for k, v in counters.items()
              if k.startswith("fault:")}
    if faults or counters.get("stall"):
        w(f"\nfaults fired: {faults}   stalls: {counters.get('stall', 0)}\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="chrome-trace JSON or JSONL file written "
                                  "by --trace / write_trace()")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only (CI gate): exit 1 unless every "
                         "event kind is known and the trace holds nonzero "
                         "scheduler decisions and iteration spans")
    args = ap.parse_args(argv)
    path = Path(args.trace)
    if not path.exists():
        print(f"trace_report: {path} not found", file=sys.stderr)
        return 1
    try:
        events, summary = load_trace(path)
    except (json.JSONDecodeError, KeyError, TypeError) as err:
        print(f"trace_report: cannot parse {path}: {err}", file=sys.stderr)
        return 1
    if args.validate:
        problems = validate(events, summary)
        if problems:
            for p in problems:
                print(f"trace_report INVALID: {p}", file=sys.stderr)
            return 1
        counters = summary.get("counters", {})
        print(f"trace_report: {path} valid — {len(events)} events, "
              f"{counters.get('scheduler', 0)} scheduler decisions, "
              f"{counters.get('iteration', 0)} iteration spans, "
              f"{len(summary.get('programs', {}))} program keys")
        return 0
    report(events, summary)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
